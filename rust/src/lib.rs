//! # Self-Indexing KVCache
//!
//! A serving-oriented reproduction of *"Self-Indexing KVCache: Predicting
//! Sparse Attention from Compressed Keys"* (AAAI 2026): the compressed key
//! representation itself is the retrieval index — 4-bit sign codes per
//! 4-channel group double as (a) the vector-quantization cluster id used
//! for compressed-domain top-k retrieval (LUT-GEMV) and (b) the exact sign
//! plane of the 2-bit quantized key magnitudes.
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — serving coordinator: paged compressed KV cache,
//!   codebooks, LUT-GEMV scoring + top-k (the decode hot path), continuous
//!   batching, scheduling, metrics. Python never runs at serve time.
//! * **L2/L1 (python/compile)** — the served GQA transformer + Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt` and executed through
//!   [`runtime`] (PJRT CPU via the `xla` crate).
//!
//! Entry points: [`coordinator::engine::Engine`] for serving,
//! [`selfindex`] for the paper's algorithm as a standalone library,
//! [`method`] for the engine↔method boundary (the `CacheMethod` registry
//! + sequence-level caches), [`baselines`] for SnapKV / Quest /
//! DoubleSparse / KIVI / k-means comparators.

// Numeric-kernel style: indexed loops over parallel buffers are the
// idiom here (they mirror the math and the paper's pseudocode); clippy's
// iterator rewrites would obscure the addressing the kernels are about.
#![allow(clippy::needless_range_loop)]

pub mod attention;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod method;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod selfindex;
pub mod substrate;
pub mod tensor;
pub mod workloads;

/// Allocation-counting allocator (see
/// [`substrate::metrics::thread_allocations`]): zero-allocation
/// guarantees on the decode hot path are enforced by tests, not
/// comments. Installed only in the crate's own test builds so release
/// binaries pay nothing and downstream crates keep their own choice of
/// `#[global_allocator]`.
#[cfg(test)]
#[global_allocator]
static GLOBAL_ALLOC: substrate::metrics::CountingAllocator =
    substrate::metrics::CountingAllocator;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
