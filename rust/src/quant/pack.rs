//! Bit-packing for the compressed cache.
//!
//! Two payload kinds, both little-endian within a byte (element 0 in the
//! least-significant bits):
//! * 2-bit magnitudes/values — 4 per byte (`pack_u2`).
//! * 4-bit sign codes — 2 per byte (`pack_codes`). The nibble IS the
//!   paper's `Code(k)` (Eq. 3): MSB of the nibble = sign of the group's
//!   channel 0. Packing codes densely is what makes the "index" free: it
//!   is the same memory the key signs occupy.

/// Pack 2-bit values (0..=3), 4 per byte. Length padded up with zeros.
pub fn pack_u2(vals: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; vals.len().div_ceil(4)];
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(v < 4, "2-bit value out of range: {v}");
        out[i / 4] |= (v & 0b11) << ((i % 4) * 2);
    }
    out
}

/// Unpack `n` 2-bit values.
pub fn unpack_u2(bytes: &[u8], n: usize) -> Vec<u8> {
    assert!(bytes.len() * 4 >= n, "not enough bytes");
    (0..n).map(|i| (bytes[i / 4] >> ((i % 4) * 2)) & 0b11).collect()
}

/// Read one 2-bit element without unpacking.
#[inline(always)]
pub fn get_u2(bytes: &[u8], i: usize) -> u8 {
    (bytes[i / 4] >> ((i % 4) * 2)) & 0b11
}

/// Pack `bits`-wide values (bits ∈ {2, 4, 8}), little-endian in a byte.
pub fn pack_bits(vals: &[u8], bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    pack_bits_into(vals, bits, &mut out);
    out
}

/// [`pack_bits`] into a caller-owned arena (cleared + refilled): the
/// decode-append path packs one token per step without allocating.
pub fn pack_bits_into(vals: &[u8], bits: u32, out: &mut Vec<u8>) {
    let per = (8 / bits) as usize;
    out.clear();
    out.resize(vals.len().div_ceil(per), 0);
    let mask = ((1u16 << bits) - 1) as u8;
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(v <= mask, "{bits}-bit value out of range: {v}");
        out[i / per] |= (v & mask) << ((i % per) as u32 * bits);
    }
}

/// Read one `bits`-wide element.
#[inline(always)]
pub fn get_bits(bytes: &[u8], i: usize, bits: u32) -> u8 {
    let per = (8 / bits) as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    (bytes[i / per] >> ((i % per) as u32 * bits)) & mask
}

/// Packed 4-bit sign codes for one token: G nibbles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCodes {
    pub bytes: Vec<u8>,
    pub groups: usize,
}

/// Pack 4-bit codes (0..=15), 2 per byte (even index in low nibble).
pub fn pack_codes(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    pack_codes_into(codes, &mut out);
    out
}

/// [`pack_codes`] into a caller-owned arena (cleared + refilled).
pub fn pack_codes_into(codes: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.resize(codes.len().div_ceil(2), 0);
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c < 16, "4-bit code out of range: {c}");
        out[i / 2] |= (c & 0x0f) << ((i % 2) * 4);
    }
}

/// Words per token for the popcount scorer: `codes_bytes` packed nibble
/// bytes rounded up to whole `u64` words.
#[inline(always)]
pub fn words_per_token(codes_bytes: usize) -> usize {
    codes_bytes.div_ceil(8)
}

/// Reinterpret token-major packed nibble bytes (from [`pack_codes`]) as
/// little-endian `u64` words, `words_per_token(codes_bytes)` per token.
/// Tail bytes of a token's last word are zero-padded, so the XOR of two
/// packed streams is zero in every padding bit — the popcount scorer
/// (`selfindex::score::score_block_popcnt`) needs no mask at score time.
/// Popcount is bit-order agnostic, so no per-bit reshuffling happens
/// here: the words carry the exact nibble layout the byte path stores.
pub fn pack_signs_u64(packed: &[u8], n_tokens: usize, codes_bytes: usize) -> Vec<u64> {
    let mut out = Vec::new();
    pack_signs_u64_into(packed, n_tokens, codes_bytes, &mut out);
    out
}

/// [`pack_signs_u64`] into a caller-owned arena (cleared + refilled):
/// the decode-append path word-packs one token per step without
/// allocating, matching the other `*_into` arena packers.
pub fn pack_signs_u64_into(
    packed: &[u8],
    n_tokens: usize,
    codes_bytes: usize,
    out: &mut Vec<u64>,
) {
    assert!(packed.len() >= n_tokens * codes_bytes, "not enough bytes");
    let wpt = words_per_token(codes_bytes);
    out.clear();
    out.resize(n_tokens * wpt, 0);
    for t in 0..n_tokens {
        let row = &packed[t * codes_bytes..(t + 1) * codes_bytes];
        for (w, chunk) in row.chunks(8).enumerate() {
            let mut le = [0u8; 8];
            le[..chunk.len()].copy_from_slice(chunk);
            out[t * wpt + w] = u64::from_le_bytes(le);
        }
    }
}

/// Accumulate per-bit set counts over word-packed token rows (`wpt`
/// words per token, from [`pack_signs_u64`]): `counts[w * 64 + b]` gains
/// one for every row whose word `w` has bit `b` set. The page tier folds
/// several block-sized slices into one counter arena and then derives
/// the page's bit-majority sketch via [`majority_from_counts`] — the
/// summaries are built from the same packed words the popcount scorer
/// reads, so the second retrieval tier is pure 1-bit material
/// (DESIGN.md §Perf iteration 9).
pub fn count_sign_bits(words: &[u64], wpt: usize, counts: &mut [u32]) {
    assert!(wpt > 0 && words.len().is_multiple_of(wpt), "ragged word rows");
    assert_eq!(counts.len(), wpt * 64, "one counter per sketch bit");
    for row in words.chunks_exact(wpt) {
        for (w, &word) in row.iter().enumerate() {
            for (b, c) in counts[w * 64..(w + 1) * 64].iter_mut().enumerate() {
                *c += ((word >> b) & 1) as u32;
            }
        }
    }
}

/// Bit-majority sketch from [`count_sign_bits`] counters over `n_tokens`
/// rows: a sketch bit is set iff strictly more than half the rows set
/// it. Ties (possible only for even `n_tokens`) resolve to 0 — any
/// deterministic choice is sound, the Hamming radius absorbs the slack.
/// Appends `counts.len() / 64` words to `out`. Padding bits beyond the
/// token's `codes_bytes` stay 0 (no row ever sets them), so sketches XOR
/// against [`pack_signs_u64`]-packed queries with no mask, exactly like
/// token words do.
pub fn majority_from_counts(counts: &[u32], n_tokens: usize, out: &mut Vec<u64>) {
    assert!(counts.len().is_multiple_of(64), "counters come in 64-bit words");
    let half = (n_tokens / 2) as u32;
    for word_counts in counts.chunks_exact(64) {
        let mut word = 0u64;
        for (b, &c) in word_counts.iter().enumerate() {
            if c > half {
                word |= 1u64 << b;
            }
        }
        out.push(word);
    }
}

/// One-shot [`count_sign_bits`] + [`majority_from_counts`] over one
/// contiguous row set (tests, benches, property oracles; the page
/// builder in `kvcache/store.rs` folds per-block slices instead).
pub fn majority_sketch(words: &[u64], wpt: usize) -> Vec<u64> {
    let mut counts = vec![0u32; wpt * 64];
    count_sign_bits(words, wpt, &mut counts);
    let mut out = Vec::with_capacity(wpt);
    majority_from_counts(&counts, words.len() / wpt, &mut out);
    out
}

/// Hamming radius of word-packed token rows around sketch `m`: the
/// largest per-row `popcount(row ⊕ m)`. Together with a query's
/// `popcount(q ⊕ m)` this lower-bounds every row's distance to the query
/// (triangle inequality), which is what lets the page tier skip whole
/// pages soundly — see `selfindex::score::page_bound`.
pub fn hamming_radius(words: &[u64], m: &[u64]) -> u32 {
    assert!(!m.is_empty() && words.len().is_multiple_of(m.len()), "ragged word rows");
    let mut r = 0u32;
    for row in words.chunks_exact(m.len()) {
        let mut d = 0u32;
        for (&x, &y) in row.iter().zip(m) {
            d += (x ^ y).count_ones();
        }
        r = r.max(d);
    }
    r
}

pub fn unpack_codes(bytes: &[u8], n: usize) -> Vec<u8> {
    assert!(bytes.len() * 2 >= n, "not enough bytes");
    (0..n).map(|i| (bytes[i / 2] >> ((i % 2) * 4)) & 0x0f).collect()
}

/// Read one 4-bit code without unpacking.
#[inline(always)]
pub fn get_code(bytes: &[u8], i: usize) -> u8 {
    (bytes[i / 2] >> ((i % 2) * 4)) & 0x0f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::{check, shrink_vec};

    #[test]
    fn u2_roundtrip_exhaustive_small() {
        for n in 0..16 {
            let vals: Vec<u8> = (0..n).map(|i| (i % 4) as u8).collect();
            assert_eq!(unpack_u2(&pack_u2(&vals), n), vals);
        }
    }

    #[test]
    fn codes_roundtrip_exhaustive_small() {
        for n in 0..16 {
            let vals: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
            assert_eq!(unpack_codes(&pack_codes(&vals), n), vals);
        }
    }

    #[test]
    fn prop_u2_roundtrip() {
        check(
            11,
            300,
            |r| {
                (0..r.below(257)).map(|_| r.below(4) as u8).collect::<Vec<_>>()
            },
            |v| {
                let rt = unpack_u2(&pack_u2(v), v.len());
                if &rt == v {
                    Ok(())
                } else {
                    Err(format!("{v:?} -> {rt:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_codes_roundtrip_with_shrink() {
        crate::substrate::prop::check_with_shrink(
            12,
            300,
            |r| {
                (0..r.below(129)).map(|_| r.below(16) as u8).collect::<Vec<_>>()
            },
            |v| shrink_vec(v),
            |v| {
                let rt = unpack_codes(&pack_codes(v), v.len());
                if &rt == v {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn random_access_matches_unpack() {
        let vals: Vec<u8> = (0..100).map(|i| (i * 7 % 16) as u8).collect();
        let packed = pack_codes(&vals);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(get_code(&packed, i), v);
        }
        let v2: Vec<u8> = (0..97).map(|i| (i * 3 % 4) as u8).collect();
        let p2 = pack_u2(&v2);
        for (i, &v) in v2.iter().enumerate() {
            assert_eq!(get_u2(&p2, i), v);
        }
    }

    #[test]
    fn sign_words_roundtrip_and_tail_padding() {
        // every codes_bytes width 1..=20 (covers sub-word tails, exactly
        // one word, and a ragged second word) must reassemble byte-exact
        // with zeroed padding bits
        for cb in 1usize..=20 {
            for n_tokens in [0usize, 1, 3, 8] {
                let bytes: Vec<u8> = (0..n_tokens * cb)
                    .map(|i| (i * 37 + 11) as u8)
                    .collect();
                let words = pack_signs_u64(&bytes, n_tokens, cb);
                let wpt = words_per_token(cb);
                assert_eq!(words.len(), n_tokens * wpt, "cb={cb} n={n_tokens}");
                for t in 0..n_tokens {
                    let row = &bytes[t * cb..(t + 1) * cb];
                    let mut rebuilt = Vec::new();
                    for w in 0..wpt {
                        rebuilt.extend_from_slice(&words[t * wpt + w].to_le_bytes());
                    }
                    assert_eq!(&rebuilt[..cb], row, "cb={cb} t={t}");
                    // padding bits beyond codes_bytes are zero
                    assert!(
                        rebuilt[cb..].iter().all(|&b| b == 0),
                        "cb={cb} t={t}: nonzero padding"
                    );
                }
            }
        }
    }

    #[test]
    fn sign_words_arena_reuse_does_not_leak_stale_bytes() {
        // refilling an arena with a shorter token run must not leave old
        // words visible, and the arena must not reallocate once warm
        let mut arena = Vec::new();
        let a: Vec<u8> = (0..4 * 8).map(|_| 0xffu8).collect();
        pack_signs_u64_into(&a, 4, 8, &mut arena);
        assert_eq!(arena, vec![u64::MAX; 4]);
        let cap = arena.capacity();
        let b = vec![0u8; 2 * 8];
        pack_signs_u64_into(&b, 2, 8, &mut arena);
        assert_eq!(arena, vec![0u64; 2]);
        assert_eq!(arena.capacity(), cap, "arena must not reallocate");
    }

    #[test]
    fn majority_sketch_votes_bitwise_and_radius_covers_every_row() {
        // 3 one-word rows: bits set in >= 2 of them win the vote
        let rows = vec![0b1011u64, 0b0011, 0b0110];
        let m = majority_sketch(&rows, 1);
        assert_eq!(m, vec![0b0011]);
        // per-row distances to the sketch: 1, 0, 2 — radius is the max
        let r = hamming_radius(&rows, &m);
        assert_eq!(r, 2);
        for &row in &rows {
            assert!((row ^ m[0]).count_ones() <= r, "radius must cover {row:#b}");
        }
    }

    #[test]
    fn majority_tie_resolves_to_zero_and_empty_input_votes_zero() {
        assert_eq!(majority_sketch(&[0b1u64, 0b0], 1), vec![0]);
        assert_eq!(majority_sketch(&[], 1), vec![0]);
    }

    #[test]
    fn majority_counts_fold_incrementally_across_slices() {
        // folding block-sized slices into one counter arena must equal the
        // one-shot sketch over the concatenation (what `close_page` relies
        // on when a page's rows span several pool blocks)
        let rows: Vec<u64> = (0..10u64)
            .map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
            .collect();
        let wpt = 2; // 5 rows of 2 words
        let mut counts = vec![0u32; wpt * 64];
        count_sign_bits(&rows[..4], wpt, &mut counts);
        count_sign_bits(&rows[4..], wpt, &mut counts);
        let mut folded = Vec::new();
        majority_from_counts(&counts, 5, &mut folded);
        assert_eq!(folded, majority_sketch(&rows, wpt));
    }

    #[test]
    fn sketch_padding_bits_stay_zero() {
        // rows from a ragged codes_bytes width: padding bits are zero in
        // every row, so they must be zero in the sketch too
        let cb = 9usize; // 2 words/token, second word has a 1-byte payload
        let bytes: Vec<u8> = (0..5 * cb).map(|i| (i * 41 + 3) as u8).collect();
        let words = pack_signs_u64(&bytes, 5, cb);
        let m = majority_sketch(&words, words_per_token(cb));
        assert_eq!(m.len(), 2);
        assert_eq!(m[1] & !0xff, 0, "padding bits beyond codes_bytes leak");
    }

    #[test]
    fn packed_sizes() {
        assert_eq!(pack_u2(&[0; 7]).len(), 2);
        assert_eq!(pack_u2(&[0; 8]).len(), 2);
        assert_eq!(pack_codes(&[0; 3]).len(), 2);
        // head_dim 64: codes 32 nibbles = 16B, mags 64×2b = 16B — the
        // storage the paper's overhead analysis counts (sign bits = D bits)
        assert_eq!(pack_codes(&[0; 16]).len(), 8);
        assert_eq!(pack_u2(&[0; 64]).len(), 16);
    }
}
