//! Token-wise low-bit quantization (paper Eq. 9-13) and bit-packing.
//!
//! * [`int2`] — asymmetric 2-bit (configurable-bit) min/max quantization
//!   per (token × 32-channel group), parameters stored in fp16 as the
//!   paper's overhead analysis assumes.
//! * [`pack`] — dense bit-packing: 2-bit payloads (4/byte) and 4-bit sign
//!   codes (2/byte), the actual in-cache storage format.

pub mod int2;
pub mod pack;

pub use int2::{dequantize_group, quantize_tokens, QuantParams, TokenQuant};
pub use pack::{pack_codes, pack_u2, unpack_codes, unpack_u2, PackedCodes};
