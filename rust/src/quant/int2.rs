//! Token-wise asymmetric min/max quantization (paper Eq. 9-11).
//!
//! Parameters are stored per (token × `group` channels) in fp16 — the
//! layout that makes single-token random access cheap (one contiguous
//! record), unlike channel-wise schemes (KIVI) that must touch every
//! channel's parameter row to reconstruct one token.

use crate::tensor::fp16::{f16_to_f32, f32_to_f16};

/// fp16-stored scale/zero-point for one quant group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: u16, // f16 bits
    pub zero: u16,  // f16 bits
}

impl QuantParams {
    pub fn scale_f32(&self) -> f32 {
        f16_to_f32(self.scale)
    }

    pub fn zero_f32(&self) -> f32 {
        f16_to_f32(self.zero)
    }
}

/// Quantized payload for a block of tokens (values unpacked u8 here;
/// the cache packs them via `pack::pack_u2`).
#[derive(Clone, Debug)]
pub struct TokenQuant {
    pub values: Vec<u8>,          // (tokens × dim), row-major
    pub params: Vec<QuantParams>, // (tokens × dim/group)
    pub dim: usize,
    pub group: usize,
    pub bits: u32,
}

/// Quantize rows of `x` ((tokens × dim) row-major) with `bits`-bit
/// asymmetric quantization per (token, group-of-`group`-channels).
///
/// qs = (max-min)/(2^B-1) (clamped to >0), zp = min; both rounded to fp16
/// *before* quantizing so the stored params reproduce the encoder exactly.
pub fn quantize_tokens(x: &[f32], dim: usize, group: usize, bits: u32) -> TokenQuant {
    let mut out = TokenQuant {
        values: vec![],
        params: vec![],
        dim,
        group,
        bits,
    };
    quantize_tokens_into(x, dim, group, bits, &mut out);
    out
}

/// [`quantize_tokens`] into a caller-owned [`TokenQuant`] arena: clears
/// and refills `out`, reusing its buffers — the decode-append hot path
/// (one token per call, every step) stays allocation-free once warm.
pub fn quantize_tokens_into(x: &[f32], dim: usize, group: usize, bits: u32, out: &mut TokenQuant) {
    assert!(dim % group == 0, "dim {dim} % group {group} != 0");
    assert!(x.len() % dim == 0);
    let tokens = x.len() / dim;
    let ng = dim / group;
    let qmax = (1u32 << bits) - 1;
    out.dim = dim;
    out.group = group;
    out.bits = bits;
    out.values.clear();
    out.values.resize(x.len(), 0);
    out.params.clear();
    out.params.reserve(tokens * ng);
    let values = &mut out.values;
    let params = &mut out.params;

    for t in 0..tokens {
        let row = &x[t * dim..(t + 1) * dim];
        for g in 0..ng {
            let seg = &row[g * group..(g + 1) * group];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in seg {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let mut qs = (hi - lo) / qmax as f32;
            if qs.is_nan() || qs <= 0.0 {
                qs = 1.0; // constant group guard (matches ref.py)
            }
            // round params through fp16 so encode/decode agree bit-exactly
            let qs16 = f32_to_f16(qs);
            let zp16 = f32_to_f16(lo);
            let qs = f16_to_f32(qs16);
            let zp = f16_to_f32(zp16);
            let qs_safe = if qs > 0.0 { qs } else { 1.0 };
            for (j, &v) in seg.iter().enumerate() {
                let q = ((v - zp) / qs_safe).round().clamp(0.0, qmax as f32);
                values[t * dim + g * group + j] = q as u8;
            }
            params.push(QuantParams { scale: qs16, zero: zp16 });
        }
    }
}

/// Dequantize one token's group segment into `out`.
#[inline]
pub fn dequantize_group(vals: &[u8], p: QuantParams, out: &mut [f32]) {
    let qs = p.scale_f32();
    let zp = p.zero_f32();
    for (o, &v) in out.iter_mut().zip(vals) {
        *o = qs * v as f32 + zp;
    }
}

impl TokenQuant {
    /// Dequantize everything back to f32 (tests / baselines).
    pub fn dequantize(&self) -> Vec<f32> {
        let ng = self.dim / self.group;
        let tokens = self.values.len() / self.dim;
        let mut out = vec![0.0f32; self.values.len()];
        for t in 0..tokens {
            for g in 0..ng {
                let p = self.params[t * ng + g];
                let base = t * self.dim + g * self.group;
                dequantize_group(
                    &self.values[base..base + self.group],
                    p,
                    &mut out[base..base + self.group],
                );
            }
        }
        out
    }

    /// Worst-case absolute reconstruction error per group (qs/2 + fp16 slop).
    pub fn error_bound(&self, token: usize, group_idx: usize) -> f32 {
        let ng = self.dim / self.group;
        let p = self.params[token * ng + group_idx];
        0.5 * p.scale_f32() + 1e-3 * p.zero_f32().abs().max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::check;
    use crate::substrate::rng::Rng;

    fn rand_rows(seed: u64, tokens: usize, dim: usize, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..tokens * dim).map(|_| r.normal_f32() * scale).collect()
    }

    #[test]
    fn error_within_bound() {
        let dim = 64;
        let x = rand_rows(1, 32, dim, 3.0);
        let q = quantize_tokens(&x, dim, 32, 2);
        let d = q.dequantize();
        let ng = dim / 32;
        for t in 0..32 {
            for g in 0..ng {
                let bound = q.error_bound(t, g);
                for j in 0..32 {
                    let i = t * dim + g * 32 + j;
                    assert!(
                        (d[i] - x[i]).abs() <= bound + 1e-4,
                        "t{t} g{g} j{j}: {} vs {} bound {bound}",
                        d[i],
                        x[i]
                    );
                }
            }
        }
    }

    #[test]
    fn values_in_range() {
        for bits in [2u32, 4] {
            let x = rand_rows(2, 16, 64, 5.0);
            let q = quantize_tokens(&x, 64, 32, bits);
            let m = (1u32 << bits) - 1;
            assert!(q.values.iter().all(|&v| (v as u32) <= m));
        }
    }

    #[test]
    fn constant_group_exact() {
        let x = vec![3.25f32; 4 * 64];
        let q = quantize_tokens(&x, 64, 32, 2);
        let d = q.dequantize();
        for (a, b) in d.iter().zip(&x) {
            assert!((a - b).abs() < 1e-2, "{a} {b}"); // fp16 zero-point slop
        }
    }

    #[test]
    fn more_bits_less_error() {
        let x = rand_rows(3, 64, 64, 2.0);
        let err = |bits| {
            let q = quantize_tokens(&x, 64, 32, bits);
            let d = q.dequantize();
            d.iter()
                .zip(&x)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
        };
        let e2 = err(2);
        let e4 = err(4);
        let e8 = err(8);
        assert!(e4 < e2 && e8 < e4, "{e2} {e4} {e8}");
    }

    #[test]
    fn prop_roundtrip_error_bounded() {
        check(
            13,
            100,
            |r| {
                let tokens = 1 + r.below(8) as usize;
                let scale = r.uniform(0.01, 10.0);
                rand_rows(r.next_u64(), tokens, 64, scale)
            },
            |x| {
                let q = quantize_tokens(x, 64, 32, 2);
                let d = q.dequantize();
                let ng = 2;
                for (i, (&a, &b)) in d.iter().zip(x.iter()).enumerate() {
                    let t = i / 64;
                    let g = (i % 64) / 32;
                    let bound = q.error_bound(t, g) + 1e-4;
                    let _ = ng;
                    if (a - b).abs() > bound {
                        return Err(format!(
                            "elem {i}: |{a} - {b}| > {bound}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matches_python_golden() {
        // cross-checked against ref.quantize_token_wise in golden.bin by
        // tests in rust/tests/golden.rs; here: deterministic sanity only.
        let x: Vec<f32> = (0..64).map(|i| i as f32 / 10.0).collect();
        let q = quantize_tokens(&x, 64, 32, 2);
        // group 0 spans 0.0..=3.1 -> qs ≈ 3.1/3
        let qs = q.params[0].scale_f32();
        assert!((qs - 3.1 / 3.0).abs() < 0.01, "{qs}");
        assert_eq!(q.values[0], 0);
        assert_eq!(q.values[31], 3);
    }
}
