//! **Table 1**: LongBench-proxy accuracy, six categories × methods
//! {Full, SnapKV, Quest, DoubleSparse, Ours(16bit), Ours(2bit)} at the
//! paper's 160-token budget (64 sinks + 96 dynamic for ours; plain 160
//! for the dynamic baselines; 160 kept tokens for SnapKV).
//!
//! Two sections:
//!  1. task accuracy through the serving engine on the trained tiny
//!     model (requires `make artifacts`; skipped otherwise);
//!  2. the mechanism table — retrieval/attention fidelity on identical
//!     synthetic states (always runs; this is what drives section 1).

mod common;

use selfindex_kv::substrate::error as anyhow;
use selfindex_kv::baselines::{
    AttentionMethod, DoubleSparse, QuestCache, SelfIndexing, SnapKv,
};
use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::MethodKind;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::Table;
use selfindex_kv::workloads::longbench::{self, category, LongBenchConfig, TASKS};

const METHODS: &[(&str, MethodKind)] = &[
    ("Full", MethodKind::Full),
    ("SnapKV", MethodKind::SnapKv),
    ("Quest", MethodKind::Quest),
    ("DoubleSparse", MethodKind::DoubleSparse),
    ("Ours", MethodKind::SelfIndex),
];

fn main() -> anyhow::Result<()> {
    let fast = common::fast_mode();
    let cfg = LongBenchConfig {
        context: if fast { 384 } else { 512 },
        items: if fast { 2 } else { 3 },
        seed: 1234,
    };

    println!(
        "== Table 1: LongBench-proxy ({} items/task, ctx {}B) ==\n",
        cfg.items, cfg.context
    );

    if common::artifacts_available() {
        let items = longbench::generate(&cfg);
        let mut table = Table::new(&{
            let mut h = vec!["Method"];
            h.extend_from_slice(TASKS);
            h.push("Avg.");
            h
        });
        for &(name, kind) in METHODS {
            let mut ecfg = EngineConfig::default();
            // paper budget: 160 total; ours: 64 sink + 96 dynamic
            ecfg.sparse_k = Some(if kind == MethodKind::SelfIndex { 96 } else { 160 });
            let scores = common::run_eval(kind, &items, ecfg)?;
            let mut row = vec![name.to_string()];
            let mut sum = 0.0;
            for &t in TASKS {
                let s = scores.get(t).copied().unwrap_or(0.0) * 100.0;
                sum += s;
                row.push(format!("{s:.1}"));
            }
            row.push(format!("{:.1}", sum / TASKS.len() as f64));
            table.row(row);
            eprintln!("  [{name}] done");
        }
        println!("{}", table.render());
        println!("categories: {}", TASKS.iter()
            .map(|t| format!("{t}={}", category(t)))
            .collect::<Vec<_>>()
            .join(" "));
    } else {
        println!("(artifacts missing — run `make artifacts` for the engine section)\n");
    }

    // ---- mechanism table (always) ----
    let trials = if fast { 3 } else { 8 };
    let tokens = if fast { 1024 } else { 2048 };
    println!(
        "\nmechanism: fidelity on identical states ({} heads × {} tokens, budget 160):\n",
        trials, tokens
    );
    type Factory = Box<dyn Fn() -> Box<dyn AttentionMethod>>;
    let factories: Vec<(&str, Factory)> = vec![
        ("SnapKV", Box::new(|| Box::new(SnapKv::new(64, 160)))),
        ("Quest", Box::new(|| Box::new(QuestCache::new(64)))),
        ("DoubleSparse", Box::new(|| Box::new(DoubleSparse::new(64)))),
        ("Ours(16bit)", Box::new(|| {
            let mut c = SelfIndexConfig::default();
            c.quant_bits = 8;
            Box::new(SelfIndexing::new(64, c))
        })),
        ("Ours(2bit)", Box::new(|| {
            Box::new(SelfIndexing::new(64, SelfIndexConfig::default()))
        })),
    ];
    let mut mt = Table::new(&["Method", "recall@160", "output cosine"]);
    for (name, f) in &factories {
        let (rec, cos) = common::run_fidelity(f.as_ref(), trials, tokens, 160);
        mt.row(vec![
            name.to_string(),
            if rec.is_nan() { "—".into() } else { format!("{rec:.3}") },
            format!("{cos:.4}"),
        ]);
    }
    println!("{}", mt.render());
    println!("paper shape: Ours ≥ Quest/DS > SnapKV; Ours(2bit) ≈ Ours(16bit)");
    Ok(())
}
