//! **Figure 4**: average retrieval quality vs sparsity ratio.
//!
//! The paper sweeps the fraction of tokens kept (2.5%–20%) on RULER-32K
//! and plots average task score per method. Mechanically, what varies is
//! how well each method's budgeted attention matches full attention as
//! the budget shrinks; we measure exactly that — per-method attention
//! fidelity (output cosine vs full attention) and retrieval recall —
//! averaged over RULER-like synthetic states, and print the series.

mod common;

use std::sync::Arc;

use selfindex_kv::baselines::{AttentionMethod, FullCache};
use selfindex_kv::eval::{cosine, mean, recall_at_k};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::method::registry::{lookup, selfindex_overlayed, BuildCtx};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::Table;
use selfindex_kv::substrate::json::Json;

/// Registry-built per-head leaf (the same construction path the engine
/// uses), with a per-method knob overlay.
fn build(name: &str, overlay: &[(String, Json)], budget_hint: usize) -> Box<dyn AttentionMethod> {
    let si = SelfIndexConfig::default();
    // layout from the *resolved* config, as the engine sizes its pool
    let eff = selfindex_overlayed(&si, overlay);
    let mgr = Arc::new(KvManager::for_head(64, &eff, 64, (1 << 14) / 64));
    let ctx = BuildCtx {
        dim: 64,
        n_layers: 1,
        kv_heads: 1,
        gqa_ratio: 1,
        budget_hint,
        mgr: &mgr,
        selfindex: &si,
        overlay,
        prompt_hash: 0,
    };
    lookup(name).expect("registered").build_head(&ctx)
}

fn main() {
    let (tokens, dim) = if common::fast_mode() { (1024, 64) } else { (4096, 64) };
    let trials = if common::fast_mode() { 2u64 } else { 6 };
    let ratios = [0.025, 0.05, 0.075, 0.10, 0.15, 0.20];

    println!("== Fig. 4: attention fidelity vs sparsity ratio ==");
    println!(
        "({tokens}-token contexts, {trials} heads per point; series = output \
         cosine vs full attention)\n"
    );

    let mut table = Table::new(&["method", "2.5%", "5%", "7.5%", "10%", "15%", "20%"]);

    type Factory = Box<dyn Fn(usize) -> Box<dyn AttentionMethod>>;
    let methods: Vec<(&str, Factory)> = vec![
        ("ours(2bit)", Box::new(|_| build("ours", &[], 0))),
        // highest payload precision in this impl
        (
            "ours(16bit)",
            Box::new(|_| build("ours", &[("quant_bits".to_string(), Json::Num(8.0))], 0)),
        ),
        ("quest", Box::new(|_| build("quest", &[], 0))),
        ("doublesparse", Box::new(|_| build("ds", &[], 0))),
        ("kmeans", Box::new(|_| build("kmeans", &[], 0))),
        // snapkv's keep set is its budget: rebuild per ratio
        ("snapkv", Box::new(|budget| build("snapkv", &[], budget))),
    ];

    for (name, factory) in &methods {
        let mut row = vec![name.to_string()];
        for &ratio in &ratios {
            let budget = ((tokens as f64 * ratio) as usize).max(1);
            let mut scores = vec![];
            for seed in 0..trials {
                let (keys, vals, query) = common::clustered_state(7 + seed, tokens, dim);
                let mut full = FullCache::new(dim);
                full.prefill(&keys, &vals, &[], 1);
                let mut b = vec![0.0; dim];
                full.attend(&query, usize::MAX, &mut b);

                let mut m: Box<dyn AttentionMethod> = factory(budget);
                // observation window: queries from a DIFFERENT part of the
                // distribution than the test query — the paper's RULER
                // setting where the relevant tokens are unknown at prefill
                // (SnapKV's structural weakness; dynamic methods are
                // unaffected since they re-retrieve per decode query).
                let mut wr = selfindex_kv::substrate::rng::Rng::new(seed ^ 0xDEAD);
                let qw: Vec<f32> = (0..8 * dim).map(|_| wr.normal_f32() * 2.0).collect();
                m.prefill(&keys, &vals, &qw, 1);
                let mut a = vec![0.0; dim];
                m.attend(&query, budget, &mut a);
                scores.push(cosine(&a, &b));
            }
            row.push(format!("{:.3}", mean(&scores)));
        }
        table.row(row);
    }
    println!("{}", table.render());

    // companion series: raw top-k recall of each retrieval index
    println!("retrieval recall@k vs exact scores (same sweep):\n");
    let mut rt = Table::new(&["method", "2.5%", "5%", "7.5%", "10%", "15%", "20%"]);
    for (name, reg) in [
        ("ours(2bit)", "ours"),
        ("quest", "quest"),
        ("doublesparse", "ds"),
        ("kmeans", "kmeans"),
    ] {
        let mut row = vec![name.to_string()];
        for &ratio in &ratios {
            let budget = ((tokens as f64 * ratio) as usize).max(1);
            let mut rs = vec![];
            for seed in 0..trials {
                let (keys, vals, query) = common::clustered_state(7 + seed, tokens, dim);
                let mut m: Box<dyn AttentionMethod> = build(reg, &[], 0);
                m.prefill(&keys, &vals, &[], 1);
                let approx = m.retrieval_scores(&query).unwrap();
                // exact over centered keys (retrieval target)
                let mu: Vec<f32> = (0..dim)
                    .map(|j| keys.iter().skip(j).step_by(dim).sum::<f32>() / tokens as f32)
                    .collect();
                let centered: Vec<f32> = keys
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v - mu[i % dim])
                    .collect();
                let mut exact = Vec::new();
                selfindex_kv::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);
                rs.push(recall_at_k(&approx, &exact, budget));
            }
            row.push(format!("{:.3}", mean(&rs)));
        }
        rt.row(row);
    }
    println!("{}", rt.render());

    // context: fidelity-per-byte — the methods are not at equal memory
    let (keys, vals, _) = common::clustered_state(7, tokens, dim);
    let mut mt = Table::new(&["method", "cache bytes @ this ctx"]);
    let mems: Vec<(&str, Box<dyn AttentionMethod>)> = vec![
        ("ours(2bit)", build("ours", &[], 0)),
        ("quest", build("quest", &[], 0)),
        ("doublesparse", build("ds", &[], 0)),
        ("kmeans", build("kmeans", &[], 0)),
        ("full fp32", build("full", &[], 0)),
    ];
    for (name, mut m) in mems {
        m.prefill(&keys, &vals, &[], 1);
        mt.row(vec![
            name.to_string(),
            selfindex_kv::substrate::benchkit::fmt_bytes(m.memory_bytes()),
        ]);
    }
    println!("{}", mt.render());
    println!("paper shape: ours stays near-flat past 7.5% and delivers its\n\
              fidelity at ~5x less memory than the fp16+index baselines");
}
