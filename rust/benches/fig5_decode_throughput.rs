//! Decode-throughput trajectory bench: the fused one-pass retrieval
//! (block-streaming score→select, DESIGN.md §Perf iteration 5) against
//! the seed's three-pass sequence (flat `score_tokens_bytelut` vector →
//! -inf masking → separate `top_k_indices` scan), plus end-to-end decode
//! steps/sec single-head and fanned out across a worker pool.
//!
//! Emits `BENCH_decode.json` (see `SIKV_BENCH_OUT`) with tokens/sec and
//! per-stage microseconds so future PRs have a machine-readable baseline
//! to compare against. Paper context: Table 4 retrieval row + Fig. 5's
//! "selection overhead is what separates sparse from fast-sparse".

mod common;

use std::time::Instant;

use selfindex_kv::baselines::{AttentionMethod, SelfIndexing};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::kvcache::store::HeadCache;
use selfindex_kv::quant::pack;
use selfindex_kv::selfindex::codes::sign_code;
use selfindex_kv::selfindex::lut::Lut;
use selfindex_kv::selfindex::score::{popcnt_kernel_name, BlockScorer, ByteLut};
use selfindex_kv::selfindex::topk::{top_k_indices, TopKStream};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::{
    fmt_duration, write_bench_json, Bench, StageTimer, Table,
};
use selfindex_kv::substrate::exec::ThreadPool;
use selfindex_kv::substrate::json::{num, obj, s};
use selfindex_kv::substrate::rng::Rng;

fn main() {
    let tokens = if common::fast_mode() { 4096 } else { 65536 };
    let dim = 64;
    let budget = 96usize; // paper's LongBench budget
    let sink_count = 64usize;
    let recent_rows = 64usize;
    let (keys, vals, query) = common::clustered_state(1234, tokens, dim);
    let bench = Bench::from_env();

    println!("== decode throughput @ {tokens} tokens, head_dim {dim}, k={budget} ==\n");

    let si = SelfIndexConfig::default();
    let mgr = KvManager::for_head(dim, &si, 64, tokens / 64 + 2);
    let pool = mgr.pool();
    let mut hc = HeadCache::new(dim, si.clone());
    hc.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
    // sink ids spread over the context, ascending (as snapkv_select picks)
    let sink_ids: Vec<u32> = (0..sink_count as u32).map(|i| i * 7).collect();
    let end = tokens - recent_rows;

    // ---- seed sequence: flat scores -> -inf masking -> heap top-k ------
    let mut scores: Vec<f32> = Vec::new();
    let mut seed_selected = Vec::new();
    let s_seed = bench.run(|| {
        let lut = Lut::build(std::hint::black_box(&query), hc.codebook());
        let blut = ByteLut::from_lut(&lut);
        hc.scores(pool, &blut, &mut scores);
        for &sk in &sink_ids {
            scores[sk as usize] = f32::NEG_INFINITY;
        }
        for t in end..tokens {
            scores[t] = f32::NEG_INFINITY;
        }
        seed_selected = top_k_indices(&scores, budget);
        std::hint::black_box(&seed_selected);
    });

    // ---- fused one-pass: stream blocks into the threshold selector ----
    let mut lut = Lut::empty(dim / 4);
    let mut blut = ByteLut::empty();
    let mut block_scores: Vec<f32> = Vec::new();
    let mut selector = TopKStream::new(budget);
    let mut fused_selected = Vec::new();
    let mut stages = StageTimer::new();
    let s_fused = bench.run(|| {
        let t_lut = Instant::now();
        lut.rebuild(std::hint::black_box(&query), hc.codebook());
        blut.rebuild(&lut);
        stages.add("lut_us", t_lut.elapsed());
        let t_sel = Instant::now();
        // the exact pipeline the serving path runs (shared implementation)
        let scorer = BlockScorer::ByteLut(&blut);
        hc.stream_select(
            pool,
            &scorer,
            end,
            &sink_ids,
            budget,
            &mut block_scores,
            &mut selector,
            &mut fused_selected,
        );
        stages.add("score_select_us", t_sel.elapsed());
        std::hint::black_box(&fused_selected);
    });

    // sanity: identical selections (masked entries excluded either way)
    let seed_unmasked: Vec<u32> = seed_selected
        .iter()
        .copied()
        .filter(|&i| scores[i as usize] != f32::NEG_INFINITY)
        .collect();
    assert_eq!(
        fused_selected, seed_unmasked,
        "fused selection must match the seed pipeline"
    );

    // ---- popcount fused pass: same stream_select, XOR+popcount kernel --
    // query prep (sign codes → packed bytes → words) happens inside the
    // closure exactly like the serving path does per step; all arenas
    let mut q_codes: Vec<u8> = Vec::new();
    let mut q_packed: Vec<u8> = Vec::new();
    let mut q_words: Vec<u64> = Vec::new();
    let mut pop_selected = Vec::new();
    let s_pop = bench.run(|| {
        q_codes.clear();
        q_codes.extend(std::hint::black_box(&query).chunks_exact(4).map(sign_code));
        pack::pack_codes_into(&q_codes, &mut q_packed);
        pack::pack_signs_u64_into(&q_packed, 1, dim / 8, &mut q_words);
        let scorer = BlockScorer::Popcnt { q_words: &q_words, dim };
        hc.stream_select(
            pool,
            &scorer,
            end,
            &sink_ids,
            budget,
            &mut block_scores,
            &mut selector,
            &mut pop_selected,
        );
        std::hint::black_box(&pop_selected);
    });
    // NOTE: popcount ranks by sign agreement, not centroid dot products —
    // selections legitimately differ from the byte-LUT pipeline, so only
    // the shape is sanity-checked here (parity vs the sign-LUT oracle is
    // pinned bit-exactly in tests/score_parity.rs)
    assert_eq!(pop_selected.len(), fused_selected.len());

    let retrieval_speedup = s_seed.mean.as_secs_f64() / s_fused.mean.as_secs_f64();
    let popcnt_score_speedup = s_fused.mean.as_secs_f64() / s_pop.mean.as_secs_f64();
    let mut table = Table::new(&["Retrieval pipeline", "Time", "vs fused"]);
    table.row(vec![
        "fused one-pass (stream+threshold)".into(),
        fmt_duration(s_fused.mean),
        "1.00x".into(),
    ]);
    table.row(vec![
        "seed three-pass (score+mask+topk)".into(),
        fmt_duration(s_seed.mean),
        format!("{retrieval_speedup:.2}x"),
    ]);
    table.row(vec![
        format!(
            "fused popcount ({} kernel)",
            popcnt_kernel_name(pack::words_per_token(dim / 8))
        ),
        fmt_duration(s_pop.mean),
        format!("{:.2}x", 1.0 / popcnt_score_speedup),
    ]);
    println!("{}", table.render());
    println!("acceptance bar: fused >= 1.5x over seed — measured {retrieval_speedup:.2}x");
    println!(
        "popcount score stage vs byte-LUT: {popcnt_score_speedup:.2}x (bench gate: >= 1.0x)\n"
    );

    // ---- hierarchical page skipping @ 1M tokens (needle retrieval) -----
    // DESIGN.md §Perf iteration 9: per-page majority sketch + Hamming
    // radius lets `stream_select` reject whole 4096-token pages against
    // the running top-k threshold. A needle workload makes the win
    // visible: homogeneous per-page background (tight radius) with
    // query-aligned needles planted in page 0, so the selector's bar
    // fills at +dim and every later page's bound falls below it. The
    // paged cache must return the SAME selection as a flat sweep.
    let hier_tokens = 1usize << 20;
    let hier_bt = 64usize;
    let page_tokens = 64 * hier_bt; // page_blocks=64 pages of 4096 tokens
    let n_pages = hier_tokens / page_tokens;
    let needles = 256usize;
    let mut pat_rng = Rng::new(0x5ee1);
    let sign_pat: Vec<f32> = (0..dim)
        .map(|_| if pat_rng.below(2) == 0 { 1.0 } else { -1.0 })
        .collect();
    let build_hier = |page_blocks: usize| {
        let cfg = SelfIndexConfig { page_blocks, ..SelfIndexConfig::default() };
        let mgr = KvManager::for_head(dim, &cfg, hier_bt, hier_tokens / hier_bt + 2);
        let mut hc = HeadCache::new(dim, cfg);
        // page 0 doubles as the prompt: needles first, then background
        let mut rows_rng = Rng::new(0xba5e);
        let mut base = vec![0.0f32; dim];
        let fill_base = |r: &mut Rng, base: &mut [f32]| {
            for b in base.iter_mut() {
                *b = if r.below(2) == 0 { 3.0 } else { -3.0 };
            }
        };
        let mut prompt = Vec::with_capacity(page_tokens * dim);
        for _ in 0..needles {
            prompt.extend(sign_pat.iter().map(|&s| 5.0 * s));
        }
        fill_base(&mut rows_rng, &mut base);
        let mut row = vec![0.0f32; dim];
        let emit_bg = |r: &mut Rng, base: &[f32], row: &mut [f32]| {
            row.copy_from_slice(base);
            for _ in 0..2 {
                let j = r.below(dim as u64) as usize;
                row[j] = -row[j];
            }
        };
        for _ in needles..page_tokens {
            emit_bg(&mut rows_rng, &base, &mut row);
            prompt.extend_from_slice(&row);
        }
        hc.ingest_prefill(&mgr, &prompt, &prompt, 0).unwrap();
        for _ in 1..n_pages {
            fill_base(&mut rows_rng, &mut base);
            for _ in 0..page_tokens {
                emit_bg(&mut rows_rng, &base, &mut row);
                hc.append(mgr.pool(), &row, &row).unwrap();
            }
        }
        assert_eq!(hc.len(), hier_tokens);
        (mgr, hc)
    };
    let (hmgr_flat, hc_flat) = build_hier(0);
    let (hmgr_paged, hc_paged) = build_hier(64);
    assert_eq!(hc_flat.pages(), 0);
    assert_eq!(hc_paged.pages(), n_pages);

    let hq_codes: Vec<u8> = sign_pat.chunks_exact(4).map(sign_code).collect();
    let hq_packed = pack::pack_codes(&hq_codes);
    let hq_words = pack::pack_signs_u64(&hq_packed, 1, dim / 8);
    let hscorer = BlockScorer::Popcnt { q_words: &hq_words, dim };
    let mut hflat_out = Vec::new();
    let mut hpaged_out = Vec::new();
    let s_hflat = bench.run(|| {
        hc_flat.stream_select(
            hmgr_flat.pool(),
            &hscorer,
            hier_tokens,
            &[],
            budget,
            &mut block_scores,
            &mut selector,
            &mut hflat_out,
        );
        std::hint::black_box(&hflat_out);
    });
    hc_paged.reset_page_stats();
    let s_hpaged = bench.run(|| {
        hc_paged.stream_select(
            hmgr_paged.pool(),
            &hscorer,
            hier_tokens,
            &[],
            budget,
            &mut block_scores,
            &mut selector,
            &mut hpaged_out,
        );
        std::hint::black_box(&hpaged_out);
    });
    assert_eq!(hflat_out, hpaged_out, "page skipping must stay bit-exact at 1M tokens");
    let (h_scanned, h_skipped) = hc_paged.page_stats();
    let page_skip_rate = h_skipped as f64 / (h_scanned.max(1)) as f64;
    let hier_retrieval_speedup = s_hflat.mean.as_secs_f64() / s_hpaged.mean.as_secs_f64();
    println!(
        "hierarchical retrieval @ {hier_tokens} tokens ({n_pages} pages): flat {} | paged {} \
         — {hier_retrieval_speedup:.1}x, skip rate {page_skip_rate:.3} \
         (gates: >= 3.0x, >= 0.9)\n",
        fmt_duration(s_hflat.mean),
        fmt_duration(s_hpaged.mean)
    );

    // ---- end-to-end decode step (single head, GQA group of 4) ---------
    let r_heads = 4usize;
    let mut ours = SelfIndexing::with_capacity(dim, si.clone(), tokens / 64 + 8);
    ours.prefill(&keys, &vals, &[], 1);
    let queries: Vec<f32> = (0..r_heads).flat_map(|_| query.clone()).collect();
    let mut outs = vec![0.0f32; r_heads * dim];
    let s_step = bench.run(|| {
        let t_at = Instant::now();
        ours.attend_group(
            std::hint::black_box(&queries),
            dim,
            budget,
            &mut outs,
        );
        stages.add("attend_group_us", t_at.elapsed());
        std::hint::black_box(&outs);
    });
    let single_steps_per_sec = 1.0 / s_step.mean.as_secs_f64();
    println!(
        "single-head decode step (append-free attend_group, R={r_heads}): {} ({:.0} steps/s)\n",
        fmt_duration(s_step.mean),
        single_steps_per_sec
    );

    // ---- parallel decode fan-out (engine-shaped: one unit per kv head) --
    let n_heads = 8usize;
    let workers = ThreadPool::default_size();
    let mut heads: Vec<SelfIndexing> = (0..n_heads)
        .map(|h| {
            let (k, v, _) = common::clustered_state(4321 + h as u64, tokens, dim);
            let mut m = SelfIndexing::with_capacity(dim, si.clone(), tokens / 64 + 8);
            m.prefill(&k, &v, &[], 1);
            m
        })
        .collect();
    let mut head_outs = vec![0.0f32; n_heads * r_heads * dim];

    let serial = bench.run(|| {
        for (m, o) in heads.iter_mut().zip(head_outs.chunks_mut(r_heads * dim)) {
            m.attend_group(std::hint::black_box(&queries), dim, budget, o);
        }
    });
    // the seed fan-out: one boxed closure per head over `scoped`
    let par_boxed = bench.run(|| {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = heads
            .iter_mut()
            .zip(head_outs.chunks_mut(r_heads * dim))
            .map(|(m, o)| {
                let q = &queries;
                let job: Box<dyn FnOnce() + Send + '_> =
                    Box::new(move || m.attend_group(q, dim, budget, o));
                job
            })
            .collect();
        workers.scoped(jobs);
    });
    // the engine's work queue: an atomic cursor over a pre-built task
    // slice (`for_each_task`), no per-job boxing
    let par_queue = bench.run(|| {
        let mut tasks: Vec<(&mut SelfIndexing, &mut [f32])> = heads
            .iter_mut()
            .zip(head_outs.chunks_mut(r_heads * dim))
            .collect();
        let q = &queries;
        workers.for_each_task(&mut tasks, |(m, o)| {
            m.attend_group(std::hint::black_box(q), dim, budget, &mut **o)
        });
    });
    let par_speedup = serial.mean.as_secs_f64() / par_queue.mean.as_secs_f64();
    let queue_vs_boxed = par_boxed.mean.as_secs_f64() / par_queue.mean.as_secs_f64();
    println!(
        "{n_heads}-head step: serial {} | scoped ({} workers) {} | work queue {} — \
         {par_speedup:.2}x vs serial, {queue_vs_boxed:.2}x vs boxed scoped",
        fmt_duration(serial.mean),
        workers.workers(),
        fmt_duration(par_boxed.mean),
        fmt_duration(par_queue.mean)
    );

    let payload = obj(vec![
        ("bench", s("decode_throughput")),
        ("context_tokens", num(tokens as f64)),
        ("budget", num(budget as f64)),
        ("seed_retrieval_us", num(s_seed.mean.as_secs_f64() * 1e6)),
        ("fused_retrieval_us", num(s_fused.mean.as_secs_f64() * 1e6)),
        ("retrieval_speedup", num(retrieval_speedup)),
        ("popcnt_score_select_us", num(s_pop.mean.as_secs_f64() * 1e6)),
        ("popcnt_score_speedup", num(popcnt_score_speedup)),
        (
            "popcnt_kernel",
            s(popcnt_kernel_name(pack::words_per_token(dim / 8))),
        ),
        ("stage_us", stages.to_json()),
        ("hier_context_tokens", num(hier_tokens as f64)),
        ("hier_pages", num(n_pages as f64)),
        ("hier_flat_sweep_us", num(s_hflat.mean.as_secs_f64() * 1e6)),
        ("hier_paged_sweep_us", num(s_hpaged.mean.as_secs_f64() * 1e6)),
        ("hier_retrieval_speedup", num(hier_retrieval_speedup)),
        ("page_skip_rate", num(page_skip_rate)),
        ("single_head_steps_per_sec", num(single_steps_per_sec)),
        ("parallel_heads", num(n_heads as f64)),
        ("parallel_workers", num(workers.workers() as f64)),
        ("serial_8head_steps_per_sec", num(1.0 / serial.mean.as_secs_f64())),
        ("parallel_8head_steps_per_sec", num(1.0 / par_queue.mean.as_secs_f64())),
        ("boxed_8head_steps_per_sec", num(1.0 / par_boxed.mean.as_secs_f64())),
        ("parallel_speedup", num(par_speedup)),
        ("taskqueue_vs_boxed", num(queue_vs_boxed)),
    ]);
    match write_bench_json("decode", payload) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_decode.json: {e}"),
    }
}
