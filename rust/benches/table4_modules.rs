//! **Table 4**: module-level latency comparison at a 16K-token context
//! (the paper's "16K token input with batch size 10" setting — we report
//! per-head single-query latencies; batch scales all rows equally).
//!
//! | Module     | paper rows                                | here |
//! |------------|-------------------------------------------|------|
//! | Clustering | Ours vs KMeans (20 iters)                 | one-pass sign codebook vs kmeans_codebook(20) |
//! | Retrieval  | Ours vs Quest (page 16) vs Full K·qᵀ      | LUT build+LUT-GEMV vs page bounds vs exact dot |
//! | Attention  | Ours (7.5%) vs Page Attention vs FA2 full | fused sparse vs page-gathered dense vs dense |
//!
//! Expected shape: clustering ≥10× faster than kmeans-20; retrieval ≥4×
//! faster than full scores; sparse attention ≥5× faster than full.

mod common;

use selfindex_kv::baselines::kmeans::kmeans_codebook;
use selfindex_kv::baselines::quest::QuestCache;
use selfindex_kv::baselines::AttentionMethod;
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::kvcache::sink::SinkStore;
use selfindex_kv::kvcache::store::HeadCache;
use selfindex_kv::quant::pack;
use selfindex_kv::selfindex::codebook::CodebookBuilder;
use selfindex_kv::selfindex::codes::sign_code;
use selfindex_kv::selfindex::lut::Lut;
use selfindex_kv::selfindex::score::{
    exact_scores, popcnt_kernel_name, score_block_bytelut, score_block_popcnt,
    score_block_popcnt_scalar, score_tokens_bytelut, BlockScorer, ByteLut,
};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::attention::dense::attend_dense;
use selfindex_kv::attention::sparse::{attend_sparse_fused, SparseAttnScratch};
use selfindex_kv::selfindex::topk::{top_k_indices, TopKStream};
use selfindex_kv::substrate::benchkit::{
    fmt_duration, write_bench_json, Bench, StageTimer, Table,
};
use selfindex_kv::substrate::json::{num, obj, s};

fn main() {
    let tokens = if common::fast_mode() { 2048 } else { 16384 };
    let dim = 64;
    let sparsity = 0.075;
    let budget = (tokens as f64 * sparsity) as usize;
    let (keys, vals, query) = common::clustered_state(42, tokens, dim);
    let bench = Bench::from_env();

    println!("== Table 4: module latency @ {tokens} tokens, head_dim {dim} ==\n");
    let mut table = Table::new(&["Module", "Method", "Time", "vs ours"]);

    // ---------------- Clustering ----------------
    // centered keys (both methods consume K')
    let mu: Vec<f32> = (0..dim)
        .map(|j| keys.iter().skip(j).step_by(dim).sum::<f32>() / tokens as f32)
        .collect();
    let centered: Vec<f32> = keys
        .iter()
        .enumerate()
        .map(|(i, &v)| v - mu[i % dim])
        .collect();

    let s_ours = bench.run(|| {
        let mut b = CodebookBuilder::new(dim / 4);
        b.accumulate(std::hint::black_box(&centered));
        std::hint::black_box(b.finalize());
    });
    // kmeans-20 is ~3 orders slower; measure fewer iters of the harness
    let quick = Bench { warmup: 0, min_iters: 2, max_iters: 3, budget: std::time::Duration::ZERO };
    let s_km = quick.run(|| {
        std::hint::black_box(kmeans_codebook(
            std::hint::black_box(&centered), dim, 20, 7,
        ));
    });
    table.row(vec![
        "Clustering".into(),
        "Ours (one-pass)".into(),
        fmt_duration(s_ours.mean),
        "1.0x".into(),
    ]);
    table.row(vec![
        "Clustering".into(),
        "KMeans (20 iters)".into(),
        fmt_duration(s_km.mean),
        format!("{:.1}x", s_km.mean.as_secs_f64() / s_ours.mean.as_secs_f64()),
    ]);

    // ---------------- Retrieval ----------------
    let mut builder = CodebookBuilder::new(dim / 4);
    builder.accumulate(&centered);
    let codebook = builder.finalize();
    let packed = selfindex_kv::selfindex::codes::encode_tokens_packed(&centered, dim);
    let mut scores = Vec::with_capacity(tokens);

    let s_lut = bench.run(|| {
        let lut = Lut::build(std::hint::black_box(&query), &codebook);
        let blut = ByteLut::from_lut(&lut);
        score_tokens_bytelut(&blut, &packed, tokens, &mut scores);
        std::hint::black_box(&scores);
    });
    let mut quest = QuestCache::new(dim);
    quest.prefill(&keys, &vals, &[], 1);
    let s_quest = bench.run(|| {
        std::hint::black_box(quest.page_bounds(std::hint::black_box(&query)));
    });
    let s_full = bench.run(|| {
        exact_scores(std::hint::black_box(&query), &centered, dim, &mut scores);
        std::hint::black_box(&scores);
    });
    table.row(vec![
        "Retrieval".into(),
        "Ours (LUT-GEMV)".into(),
        fmt_duration(s_lut.mean),
        "1.0x".into(),
    ]);
    table.row(vec![
        "Retrieval".into(),
        "Quest (page=16)".into(),
        fmt_duration(s_quest.mean),
        format!("{:.2}x", s_quest.mean.as_secs_f64() / s_lut.mean.as_secs_f64()),
    ]);
    table.row(vec![
        "Retrieval".into(),
        "Full K·qT".into(),
        fmt_duration(s_full.mean),
        format!("{:.2}x", s_full.mean.as_secs_f64() / s_lut.mean.as_secs_f64()),
    ]);

    // ---------------- Attention ----------------
    let si = SelfIndexConfig::default();
    let mgr = KvManager::for_head(dim, &si, 64, tokens / 64 + 2);
    let pool = mgr.pool();
    let mut hc = HeadCache::new(dim, si.clone());
    hc.ingest_prefill(&mgr, &keys, &vals, 0).unwrap();
    let lut = Lut::build(&query, hc.codebook());
    let blut = ByteLut::from_lut(&lut);
    let mut sc = Vec::new();
    hc.scores(pool, &blut, &mut sc);
    let selected = top_k_indices(&sc, budget);
    let sinks = SinkStore::default();
    let mut scratch = SparseAttnScratch::new(dim);
    let mut out = vec![0.0f32; dim];

    let s_sparse = bench.run(|| {
        attend_sparse_fused(
            std::hint::black_box(&query), &hc, pool, &selected, &sinks, &[],
            &mut scratch, &mut out,
        );
        std::hint::black_box(&out);
    });
    // "page attention": dense attention over Quest-selected pages (7.5%)
    let s_page = bench.run(|| {
        quest.attend(std::hint::black_box(&query), budget, &mut out);
        std::hint::black_box(&out);
    });
    let s_dense = bench.run(|| {
        attend_dense(std::hint::black_box(&query), &keys, &vals, tokens, &mut out);
        std::hint::black_box(&out);
    });
    table.row(vec![
        "Attention".into(),
        format!("Ours ({:.1}%)", sparsity * 100.0),
        fmt_duration(s_sparse.mean),
        "1.0x".into(),
    ]);
    table.row(vec![
        "Attention".into(),
        format!("Page Attention ({:.1}%)", sparsity * 100.0),
        fmt_duration(s_page.mean),
        format!("{:.2}x", s_page.mean.as_secs_f64() / s_sparse.mean.as_secs_f64()),
    ]);
    table.row(vec![
        "Attention".into(),
        "Flash Attention2 (Full)".into(),
        fmt_duration(s_dense.mean),
        format!("{:.2}x", s_dense.mean.as_secs_f64() / s_sparse.mean.as_secs_f64()),
    ]);

    println!("{}", table.render());
    println!("paper shape: clustering >10x, retrieval >4x vs full, attention >5x vs full");

    // ---------------- implementation ablations (§Perf design choices) ----
    println!("\nscorer implementation ablation (same workload):\n");
    let mut at = Table::new(&["variant", "Time", "vs byte-LUT"]);
    let lut2 = Lut::build(&query, &codebook);
    let blut2 = ByteLut::from_lut(&lut2);
    let s_byte = bench.run(|| {
        score_tokens_bytelut(&blut2, &packed, tokens, &mut scores);
        std::hint::black_box(&scores);
    });
    let s_nib = bench.run(|| {
        selfindex_kv::selfindex::score::score_tokens(
            &lut2, &packed, tokens, &mut scores);
        std::hint::black_box(&scores);
    });
    at.row(vec!["byte-combined LUT (G/2 lookups)".into(),
                fmt_duration(s_byte.mean), "1.0x".into()]);
    at.row(vec!["nibble LUT (G lookups)".into(),
                fmt_duration(s_nib.mean),
                format!("{:.2}x", s_nib.mean.as_secs_f64() / s_byte.mean.as_secs_f64())]);

    // popcount rows (§Perf iteration 8): same workload scored as
    // XOR+popcount over the word-packed sign codes — block-kernel
    // apples-to-apples against the block byte-LUT scorer
    let q_codes: Vec<u8> = query.chunks_exact(4).map(sign_code).collect();
    let q_packed = pack::pack_codes(&q_codes);
    let q_words = pack::pack_signs_u64(&q_packed, 1, dim / 8);
    let words = pack::pack_signs_u64(&packed, tokens, dim / 8);
    let mut block_out = vec![0.0f32; tokens];
    let s_blk = bench.run(|| {
        std::hint::black_box(score_block_bytelut(
            &blut2,
            std::hint::black_box(&packed),
            tokens,
            &mut block_out,
        ));
    });
    let s_pop = bench.run(|| {
        std::hint::black_box(score_block_popcnt(
            &q_words,
            std::hint::black_box(&words),
            tokens,
            dim,
            &mut block_out,
        ));
    });
    let s_pop_scalar = bench.run(|| {
        std::hint::black_box(score_block_popcnt_scalar(
            &q_words,
            std::hint::black_box(&words),
            tokens,
            dim,
            &mut block_out,
        ));
    });
    let kernel = popcnt_kernel_name(q_words.len());
    let popcnt_vs_bytelut = s_blk.mean.as_secs_f64() / s_pop.mean.as_secs_f64();
    at.row(vec!["byte-LUT block kernel (8-tok unroll)".into(),
                fmt_duration(s_blk.mean),
                format!("{:.2}x", s_blk.mean.as_secs_f64() / s_byte.mean.as_secs_f64())]);
    at.row(vec![format!("popcount block kernel ({kernel})"),
                fmt_duration(s_pop.mean),
                format!("{:.2}x", s_pop.mean.as_secs_f64() / s_byte.mean.as_secs_f64())]);
    at.row(vec!["popcount scalar (always-compiled)".into(),
                fmt_duration(s_pop_scalar.mean),
                format!("{:.2}x",
                        s_pop_scalar.mean.as_secs_f64() / s_byte.mean.as_secs_f64())]);
    println!("{}", at.render());
    println!(
        "popcount vs byte-LUT block kernel: {popcnt_vs_bytelut:.2}x \
         (bench gate: >= 1.0x)\n"
    );

    let score_payload = obj(vec![
        ("bench", s("score_kernels")),
        ("context_tokens", num(tokens as f64)),
        ("bytelut_us", num(s_byte.mean.as_secs_f64() * 1e6)),
        ("nibble_us", num(s_nib.mean.as_secs_f64() * 1e6)),
        ("bytelut_block_us", num(s_blk.mean.as_secs_f64() * 1e6)),
        ("popcnt_us", num(s_pop.mean.as_secs_f64() * 1e6)),
        ("popcnt_scalar_us", num(s_pop_scalar.mean.as_secs_f64() * 1e6)),
        ("popcnt_kernel", s(kernel)),
        ("popcnt_vs_bytelut", num(popcnt_vs_bytelut)),
        (
            "popcnt_tokens_per_sec",
            num(tokens as f64 / s_pop.mean.as_secs_f64()),
        ),
        (
            "bytelut_tokens_per_sec",
            num(tokens as f64 / s_blk.mean.as_secs_f64()),
        ),
    ]);
    match write_bench_json("score", score_payload) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("failed to write BENCH_score.json: {e}"),
    }

    // ---------------- per-stage decode decomposition --------------------
    // The fused pipeline has no standalone "select" stage: scoring and
    // threshold top-k happen in the same block pass (so there is no flat
    // score vector, no -inf sweep, no second O(L) scan to time). Stages
    // shown per decode step; "score+select" is the fused pass.
    println!("per-stage decode pipeline (seed three-pass vs fused one-pass):\n");
    let mut seed_stages = StageTimer::new();
    let mut fused_stages = StageTimer::new();
    let mut flat = Vec::new();
    let mut sel_out = Vec::new();
    bench.run(|| {
        let scored = seed_stages.time("score", || {
            hc.scores(pool, &blut, &mut flat);
        });
        std::hint::black_box(scored);
        seed_stages.time("select", || {
            sel_out = top_k_indices(&flat, budget);
        });
        std::hint::black_box(&sel_out);
    });
    let mut selector = TopKStream::new(budget);
    let mut block_scores = Vec::new();
    bench.run(|| {
        fused_stages.time("score+select", || {
            // the exact pipeline the serving path runs (shared impl)
            let scorer = BlockScorer::ByteLut(&blut);
            hc.stream_select(
                pool,
                &scorer,
                tokens,
                &[],
                budget,
                &mut block_scores,
                &mut selector,
                &mut sel_out,
            );
        });
        std::hint::black_box(&sel_out);
    });
    let attend_us = s_sparse.mean.as_secs_f64() * 1e6;
    let mut st_tab = Table::new(&["stage", "seed", "fused"]);
    st_tab.row(vec![
        "score".into(),
        format!("{:.1}µs", seed_stages.mean_us("score")),
        "(fused)".into(),
    ]);
    st_tab.row(vec![
        "select".into(),
        format!("{:.1}µs", seed_stages.mean_us("select")),
        "(fused)".into(),
    ]);
    st_tab.row(vec![
        "score+select".into(),
        format!(
            "{:.1}µs",
            seed_stages.mean_us("score") + seed_stages.mean_us("select")
        ),
        format!("{:.1}µs", fused_stages.mean_us("score+select")),
    ]);
    st_tab.row(vec![
        "attend".into(),
        format!("{attend_us:.1}µs"),
        format!("{attend_us:.1}µs"),
    ]);
    println!("{}", st_tab.render());

    println!("cache block-size sweep (prefill ingest + one scoring pass):\n");
    let mut bt_tab = Table::new(&["block_tokens", "ingest", "score"]);
    for &bt in &[16usize, 64, 256] {
        let mgr2 = KvManager::for_head(dim, &si, bt, tokens / bt + 2);
        let pool2 = mgr2.pool();
        let mut hc2 = HeadCache::new(dim, si.clone());
        let t0 = std::time::Instant::now();
        hc2.ingest_prefill(&mgr2, &keys, &vals, 0).unwrap();
        let ingest = t0.elapsed();
        let mut sc2 = Vec::new();
        let s = bench.run(|| {
            hc2.scores(pool2, &blut2, &mut sc2);
            std::hint::black_box(&sc2);
        });
        bt_tab.row(vec![bt.to_string(), fmt_duration(ingest),
                        fmt_duration(s.mean)]);
    }
    println!("{}", bt_tab.render());
}
