//! **Table 2**: RULER-like accuracy across 13 tasks at 7.5% sparsity
//! (scaled context; the paper uses 32K prompts on Llama-3.1-8B).
//!
//! Engine section runs the trained tiny model over the task suite per
//! method; the fidelity section reports the retrieval mechanism at the
//! same sparsity on matched synthetic states.

mod common;

use selfindex_kv::substrate::error as anyhow;
use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::MethodKind;
use selfindex_kv::substrate::benchkit::Table;
use selfindex_kv::workloads::ruler::{self, RulerConfig, TASKS};

const METHODS: &[(&str, MethodKind)] = &[
    ("Full", MethodKind::Full),
    ("SnapKV", MethodKind::SnapKv),
    ("Quest", MethodKind::Quest),
    ("DoubleSparse", MethodKind::DoubleSparse),
    ("Ours", MethodKind::SelfIndex),
];

fn main() -> anyhow::Result<()> {
    let fast = common::fast_mode();
    let cfg = RulerConfig {
        context: if fast { 384 } else { 512 },
        items: if fast { 1 } else { 2 },
        seed: 99,
    };
    println!(
        "== Table 2: RULER-proxy @ 7.5% sparsity (ctx {}B, {} items/task) ==\n",
        cfg.context, cfg.items
    );

    if !common::artifacts_available() {
        println!("(artifacts missing — run `make artifacts`)");
        return Ok(());
    }
    let items = ruler::generate(&cfg);
    let mut table = Table::new(&{
        let mut h = vec!["Method"];
        h.extend_from_slice(TASKS);
        h.push("Avg.");
        h
    });
    for &(name, kind) in METHODS {
        let mut ecfg = EngineConfig::default();
        // ratio mode: 7.5% of context per step (paper's protocol)
        ecfg.sparse_k = None;
        ecfg.sparsity = 0.075;
        let scores = common::run_eval(kind, &items, ecfg)?;
        let mut row = vec![name.to_string()];
        let mut sum = 0.0;
        for &t in TASKS {
            let s = scores.get(t).copied().unwrap_or(0.0) * 100.0;
            sum += s;
            row.push(format!("{s:.0}"));
        }
        row.push(format!("{:.1}", sum / TASKS.len() as f64));
        table.row(row);
        eprintln!("  [{name}] done");
    }
    println!("{}", table.render());
    println!("paper shape: SnapKV collapses on NS3/NM2/NM3; Ours tracks Full");
    Ok(())
}
