//! **Figure 5**: KV-cache memory footprint and decode throughput vs
//! prompt length, methods {Ours (7.5%), KIVI-2bit, Full/FA2}.
//!
//! For each length: prefill a batch of sequences, then time a fixed
//! number of decode steps; report (a) cache bytes after prefill and
//! (b) decode tokens/second.

mod common;

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;
use std::time::Instant;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::substrate::benchkit::{fmt_bytes, Table};
use selfindex_kv::workloads::corpus::{context_with_facts, KvFact};
use selfindex_kv::substrate::rng::Rng;

const METHODS: &[(&str, MethodKind)] = &[
    ("Ours(7.5%)", MethodKind::SelfIndex),
    ("KIVI-2bit", MethodKind::Kivi),
    ("Full(FA2)", MethodKind::Full),
];

fn main() -> anyhow::Result<()> {
    if !common::artifacts_available() {
        println!("(artifacts missing — run `make artifacts`)");
        return Ok(());
    }
    let fast = common::fast_mode();
    let lengths: &[usize] = if fast { &[256, 1024] } else { &[256, 1024, 4096] };
    let batch = 4usize;
    let decode_tokens = if fast { 8 } else { 24 };

    println!("== Fig. 5: memory + decode throughput vs prompt length (batch {batch}) ==\n");
    let mut table = Table::new(&["Length", "Method", "KV bytes", "decode tok/s"]);

    for &len in lengths {
        for &(name, kind) in METHODS {
            let mut ecfg = EngineConfig::default();
            ecfg.max_batch = batch;
            ecfg.max_new_tokens = decode_tokens;
            ecfg.sparse_k = None;
            ecfg.sparsity = 0.075;
            let mut engine =
                Engine::new(Path::new(&common::artifact_dir()), ecfg, kind)?;

            let mut r = Rng::new(len as u64);
            for _ in 0..batch {
                let fact = KvFact::random(&mut r);
                let mut p = context_with_facts(&mut r, len - 8, &[fact.clone()], &[0.4]);
                p.extend_from_slice(&fact.query());
                engine.submit(p, decode_tokens)?;
            }
            // run prefills until the whole batch is resident
            while engine.running() < batch {
                engine.step()?;
            }
            let bytes = engine.cache_bytes();
            // timed decode phase
            let t0 = Instant::now();
            let before = engine.metrics.counter("engine.decoded_tokens").get();
            engine.run_to_completion()?;
            let decoded =
                engine.metrics.counter("engine.decoded_tokens").get() - before;
            let tps = decoded as f64 / t0.elapsed().as_secs_f64();
            table.row(vec![
                len.to_string(),
                name.to_string(),
                fmt_bytes(bytes),
                format!("{tps:.1}"),
            ]);
            eprintln!("  [{name} @ {len}] done");
        }
    }
    println!("{}", table.render());
    println!("paper shape: ours ~5x smaller than full, throughput above full;\n\
              KIVI matches memory but decode lags (decompress-then-compute)");
    Ok(())
}
