//! **Figure 5**: KV-cache memory footprint and decode throughput vs
//! prompt length, methods {Ours (7.5%), KIVI-2bit, Full/FA2}.
//!
//! For each length: prefill a batch of sequences, then time a fixed
//! number of decode steps; report (a) cache bytes after prefill and
//! (b) decode tokens/second.
//!
//! Since the memory-manager PR this bench also drives an **oversubscribed
//! trace** over the engine-wide shared block pool (no PJRT artifacts
//! needed — the trace runs the shipped `ServingEngine` over the
//! `NativeExecutor` backend): admission on exact free-block accounting,
//! preemption when a decode step cannot fit, prefix-block adoption across
//! identical prompts. It reports pool occupancy, preemption and
//! prefix-hit counts, and emits `BENCH_memory.json` (uploaded as a CI
//! artifact next to `BENCH_decode.json`).
//!
//! Since the tiered-storage PR the bench also measures the
//! **resume-vs-recompute crossover**: the same oversubscribed pair run
//! uncontended, with plain drop-and-re-prefill eviction, and with
//! block-granular swap to the host tier. It asserts the swap run is
//! bit-exact versus never having been evicted with strictly fewer
//! re-prefills, and emits `resume_speedup` / `swap_fallback_rate`
//! (gated by `rust/BENCH_baseline.json`).

mod common;

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind, NativeExecutor, Outcome, ServingEngine};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::method::registry::{lookup, BuildCtx, CacheMethod};
use selfindex_kv::method::SequenceCache;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::{fmt_bytes, write_bench_json, Table};
use selfindex_kv::substrate::json::{num, obj, s};
use selfindex_kv::substrate::rng::Rng;
use selfindex_kv::workloads::corpus::{context_with_facts, KvFact};

const METHODS: &[(&str, MethodKind)] = &[
    ("Ours(7.5%)", MethodKind::SelfIndex),
    ("KIVI-2bit", MethodKind::Kivi),
    ("Full(FA2)", MethodKind::Full),
];

// --- the oversubscribed memory-manager trace (artifact-free) ----------

const DIM: usize = 64;
const LAYERS: usize = 2;
const KVH: usize = 2;
const R: usize = 2;
const BT: usize = 64;
const BUDGET: usize = 48;

/// Deterministic kv-head-major prompt K/V for one layer of one request.
/// `prompt_id` (not request id) seeds the data, so requests sharing a
/// prompt id produce byte-identical blocks and adopt through the prefix
/// registry.
fn prompt_kv(prompt_id: u64, layer: usize, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(0xF16_5000 + prompt_id * 31 + layer as u64);
    let keys = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    let vals = (0..KVH * tokens * DIM).map(|_| r.normal_f32()).collect();
    (keys, vals)
}

struct TraceStats {
    completed: usize,
    preemptions: usize,
    peak_used_blocks: usize,
    steps: usize,
}

/// Drive the shipped `ServingEngine` over a `NativeExecutor` bound to
/// `mgr`'s pool. `prompts[i]` is request i's prompt id — duplicates
/// submit byte-identical prompts (the executor derives its synthetic K/V
/// from prompt content), so they share prefix blocks through adoption.
fn run_trace(
    mgr: &Arc<KvManager>,
    prompts: &[u64],
    prompt_tokens: usize,
    max_new: usize,
    max_batch: usize,
) -> TraceStats {
    let exec = NativeExecutor::new(
        DIM,
        LAYERS,
        KVH,
        R,
        BUDGET,
        SelfIndexConfig::default(),
        Arc::clone(mgr),
    );
    let cfg = EngineConfig {
        max_batch,
        block_tokens: BT,
        // churn is the point of this trace; the thrash cutoff is
        // tests/chaos_engine.rs's job
        preempt_budget: 100,
        ..EngineConfig::default()
    };
    let mut eng = ServingEngine::new(cfg, exec).expect("valid config");
    for &pid in prompts {
        let prompt = (0..prompt_tokens)
            .map(|t| (pid as u8).wrapping_mul(41) ^ (t as u8).wrapping_mul(29))
            .collect();
        eng.submit(prompt, max_new).expect("queue admits the trace");
    }

    let mut stats = TraceStats { completed: 0, preemptions: 0, peak_used_blocks: 0, steps: 0 };
    for _ in 0..200_000 {
        if eng.is_drained() {
            stats.completed = eng
                .take_results()
                .iter()
                .filter(|r| r.outcome == Outcome::Completed)
                .count();
            stats.preemptions = eng.metrics.counter("engine.preemptions").get() as usize;
            stats.steps = eng.step_index() as usize;
            return stats;
        }
        eng.step().expect("no state drift");
        stats.peak_used_blocks = stats.peak_used_blocks.max(mgr.pool().used_blocks());
    }
    panic!("oversubscribed trace did not converge");
}

/// One run of the resume-vs-recompute crossover trace (DESIGN.md §Tiered
/// storage): a survivor whose decode grows past a block boundary plus a
/// victim that never grows. Under a tight pool the survivor's boundary
/// decode forces the victim out exactly once; it comes back either by
/// host-tier resume (`swap = true`) or by chunked re-prefill
/// (`swap = false`). Geometry (BT = 64, `LAYERS * KVH = 4` pool blocks
/// per cache block): survivor 126 tokens = 8 pool blocks growing to 12,
/// victim 120 tokens = 8 for life — 16 blocks admit both, the boundary
/// step finds `free 0 < step 4`, and re-admission stays blocked until
/// the survivor completes. Returns per-request generated bytes + final
/// attention outputs (the bit-exactness witnesses) and the step/counter
/// readings the crossover metrics are built from.
struct CrossoverRun {
    generated: Vec<Vec<u8>>,
    finals: Vec<Vec<f32>>,
    steps: u64,
    re_prefills: u64,
    swap_outs: u64,
    swap_ins: u64,
    swap_fallbacks: u64,
}

fn crossover_run(swap: bool, capacity_blocks: usize) -> CrossoverRun {
    let si = SelfIndexConfig::default();
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, capacity_blocks));
    let exec = NativeExecutor::new(DIM, LAYERS, KVH, R, BUDGET, si, Arc::clone(&mgr));
    let mut cfg = EngineConfig {
        max_batch: 2,
        block_tokens: BT,
        // two chunks per prompt: a re-prefill pays >= 2 steps where a
        // host-tier resume pays 1 — the crossover the bench measures
        prefill_chunk_tokens: 64,
        preempt_budget: 8,
        ..EngineConfig::default()
    };
    cfg.swap.enabled = swap;
    cfg.swap.swap_cost = 0.1;
    cfg.swap.recompute_cost = 1.0;
    cfg.swap.cold_after_sweeps = 2; // victim chills while the survivor runs
    let mut eng = ServingEngine::new(cfg, exec).expect("valid config");
    let prompt = |pid: u64, len: usize| -> Vec<u8> {
        (0..len)
            .map(|t| (pid as u8).wrapping_mul(41) ^ (t as u8).wrapping_mul(29))
            .collect()
    };
    let mut ids = vec![];
    for (p, max_new) in [(prompt(11, 126), 30), (prompt(13, 120), 8)] {
        ids.push(eng.submit(p, max_new).expect("queue admits the pair").id);
    }
    let mut res = eng.run_to_completion().expect("no state drift");
    assert!(
        res.iter().all(|r| r.outcome == Outcome::Completed),
        "crossover trace must complete every request"
    );
    res.sort_by_key(|r| r.id);
    assert!(
        eng.executor().mgr().pool().free_blocks() == capacity_blocks
            && eng.executor().mgr().tier().entries() == 0,
        "crossover trace must drain device pool and host tier"
    );
    CrossoverRun {
        generated: res.iter().map(|r| r.generated.clone()).collect(),
        finals: ids.iter().map(|id| eng.executor().finals()[id].clone()).collect(),
        steps: eng.step_index(),
        re_prefills: eng.metrics.counter("engine.retries").get(),
        swap_outs: eng.metrics.counter("engine.swap_outs").get(),
        swap_ins: eng.metrics.counter("engine.swap_ins").get(),
        swap_fallbacks: eng.metrics.counter("engine.swap_fallbacks").get(),
    }
}

/// Pool bytes for one prefilled sequence vs a second identical one on the
/// same manager: the prefix registry counts shared blocks once, so the
/// pair lands strictly below 2x.
fn prefix_sharing_ratio(prompt_tokens: usize) -> (usize, usize, f64) {
    let si = SelfIndexConfig::default();
    let overlay = vec![];
    let entry = lookup("selfindex").unwrap();
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, 256));
    let ctx = BuildCtx {
        dim: DIM,
        n_layers: LAYERS,
        kv_heads: KVH,
        gqa_ratio: R,
        budget_hint: prompt_tokens,
        mgr: &mgr,
        selfindex: &si,
        overlay: &overlay,
        prompt_hash: 0,
    };
    let mut build = || {
        let mut c = entry.build_seq(&ctx);
        for l in 0..LAYERS {
            let (keys, vals) = prompt_kv(0, l, prompt_tokens);
            c.prefill_layer(l, &keys, &vals, &[]);
        }
        c
    };
    let a = build();
    let single = mgr.pool().used_bytes();
    let b = build();
    let pair = mgr.pool().used_bytes();
    drop((a, b));
    (single, pair, pair as f64 / single as f64)
}

fn main() -> anyhow::Result<()> {
    let fast = common::fast_mode();

    // ---- oversubscribed shared-pool trace (runs everywhere) ----
    let prompt_tokens = 128;
    let max_new = if fast { 48 } else { 96 };
    // 8 requests over 4 distinct prompts (two copies each): adoption
    // halves the prefill footprint, and the pool is still far too small
    // for the full set — the run finishes via preemption, not panic
    let prompts: [u64; 8] = [0, 1, 2, 3, 0, 1, 2, 3];
    let capacity_blocks = 40;
    let si = SelfIndexConfig::default();
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, capacity_blocks));

    println!(
        "== memory manager: oversubscribed trace ({} reqs, {} distinct prompts, \
         pool {capacity_blocks} blocks) ==\n",
        prompts.len(),
        4
    );
    let t0 = Instant::now();
    let tr = run_trace(&mgr, &prompts, prompt_tokens, max_new, 6);
    let secs = t0.elapsed().as_secs_f64();
    let leak_free = mgr.pool().free_blocks() == mgr.pool().capacity_blocks();
    let (single_bytes, pair_bytes, sharing_ratio) = prefix_sharing_ratio(prompt_tokens);

    let mut mm_tab = Table::new(&["metric", "value"]);
    mm_tab.row(vec!["completed".into(), format!("{}/{}", tr.completed, prompts.len())]);
    mm_tab.row(vec!["scheduler steps".into(), tr.steps.to_string()]);
    mm_tab.row(vec!["preemptions".into(), tr.preemptions.to_string()]);
    mm_tab.row(vec![
        "peak pool occupancy".into(),
        format!("{}/{} blocks", tr.peak_used_blocks, capacity_blocks),
    ]);
    mm_tab.row(vec!["prefix hits".into(), mgr.prefix_hits().to_string()]);
    mm_tab.row(vec!["prefix misses".into(), mgr.prefix_misses().to_string()]);
    mm_tab.row(vec!["leak-free after drain".into(), leak_free.to_string()]);
    mm_tab.row(vec![
        "2 identical seqs vs 1".into(),
        format!("{} vs {} ({sharing_ratio:.2}x)", fmt_bytes(pair_bytes), fmt_bytes(single_bytes)),
    ]);
    println!("{}", mm_tab.render());
    assert_eq!(tr.completed, prompts.len(), "oversubscribed trace must finish");
    assert!(leak_free, "pool must drain to capacity after the trace");

    // ---- resume-vs-recompute crossover (tiered KV storage) ----
    // three deterministic runs of the same pair: uncontended reference,
    // oversubscribed with plain eviction, oversubscribed with the host
    // tier. Swap must be bit-exact vs never having been evicted and must
    // re-prefill strictly less; the step ratio is the measured speedup.
    println!("== tiered storage: resume-vs-recompute crossover ==\n");
    let uncontended = crossover_run(false, 24);
    let evicting = crossover_run(false, 16);
    let swapping = crossover_run(true, 16);
    assert_eq!(
        uncontended.generated, evicting.generated,
        "drop + recompute must replay bit-identically"
    );
    assert_eq!(
        (&uncontended.generated, &uncontended.finals),
        (&swapping.generated, &swapping.finals),
        "swap + resume must be bit-exact vs never having been evicted"
    );
    assert!(swapping.swap_ins >= 1, "the tight pool must swap and resume");
    assert_eq!(evicting.swap_ins, 0, "swap disabled never touches the tier");
    assert!(
        swapping.re_prefills < evicting.re_prefills,
        "the tier must re-prefill strictly less (swap {} vs evict {})",
        swapping.re_prefills,
        evicting.re_prefills
    );
    let resume_speedup = evicting.steps as f64 / swapping.steps as f64;
    let swap_fallback_rate =
        swapping.swap_fallbacks as f64 / swapping.swap_outs.max(1) as f64;
    let mut xo_tab = Table::new(&["run", "steps", "re-prefills", "swap out/in"]);
    for (name, r) in [
        ("uncontended (24 blk)", &uncontended),
        ("evicting (16 blk)", &evicting),
        ("swapping (16 blk)", &swapping),
    ] {
        xo_tab.row(vec![
            name.into(),
            r.steps.to_string(),
            r.re_prefills.to_string(),
            format!("{}/{}", r.swap_outs, r.swap_ins),
        ]);
    }
    xo_tab.row(vec!["resume speedup".into(), format!("{resume_speedup:.3}x"), "".into(), "".into()]);
    println!("{}", xo_tab.render());

    let payload = obj(vec![
        ("bench", s("memory")),
        ("prompt_tokens", num(prompt_tokens as f64)),
        ("max_new_tokens", num(max_new as f64)),
        ("requests", num(prompts.len() as f64)),
        ("distinct_prompts", num(4.0)),
        ("pool_capacity_blocks", num(capacity_blocks as f64)),
        ("peak_used_blocks", num(tr.peak_used_blocks as f64)),
        ("peak_occupancy", num(tr.peak_used_blocks as f64 / capacity_blocks as f64)),
        ("preemptions", num(tr.preemptions as f64)),
        ("prefix_hits", num(mgr.prefix_hits() as f64)),
        ("prefix_misses", num(mgr.prefix_misses() as f64)),
        ("scheduler_steps", num(tr.steps as f64)),
        ("trace_secs", num(secs)),
        ("single_seq_pool_bytes", num(single_bytes as f64)),
        ("two_shared_seq_pool_bytes", num(pair_bytes as f64)),
        ("sharing_ratio", num(sharing_ratio)),
        ("resume_speedup", num(resume_speedup)),
        ("swap_fallback_rate", num(swap_fallback_rate)),
        ("crossover_steps_evict", num(evicting.steps as f64)),
        ("crossover_steps_swap", num(swapping.steps as f64)),
        ("re_prefills_evict", num(evicting.re_prefills as f64)),
        ("re_prefills_swap", num(swapping.re_prefills as f64)),
        ("swap_outs", num(swapping.swap_outs as f64)),
        ("swap_ins", num(swapping.swap_ins as f64)),
    ]);
    match write_bench_json("memory", payload) {
        Ok(p) => println!("wrote {}\n", p.display()),
        Err(e) => eprintln!("failed to write BENCH_memory.json: {e}\n"),
    }

    // ---- engine-level footprint/throughput sweep (needs artifacts) ----
    if !common::artifacts_available() {
        println!("(artifacts missing — engine sweep skipped; run `make artifacts`)");
        return Ok(());
    }
    let lengths: &[usize] = if fast { &[256, 1024] } else { &[256, 1024, 4096] };
    let batch = 4usize;
    let decode_tokens = if fast { 8 } else { 24 };

    println!("== Fig. 5: memory + decode throughput vs prompt length (batch {batch}) ==\n");
    let mut table = Table::new(&["Length", "Method", "KV bytes", "decode tok/s"]);

    for &len in lengths {
        for &(name, kind) in METHODS {
            let mut ecfg = EngineConfig::default();
            ecfg.max_batch = batch;
            ecfg.max_new_tokens = decode_tokens;
            ecfg.sparse_k = None;
            ecfg.sparsity = 0.075;
            let mut engine = Engine::new(Path::new(&common::artifact_dir()), ecfg, kind)?;

            let mut r = Rng::new(len as u64);
            for _ in 0..batch {
                let fact = KvFact::random(&mut r);
                let mut p = context_with_facts(&mut r, len - 8, &[fact.clone()], &[0.4]);
                p.extend_from_slice(&fact.query());
                engine.submit(p, decode_tokens)?;
            }
            // run prefills until the whole batch is resident
            while engine.running() < batch {
                engine.step()?;
            }
            let bytes = engine.cache_bytes();
            // timed decode phase
            let t0 = Instant::now();
            let before = engine.metrics.counter("engine.decoded_tokens").get();
            engine.run_to_completion()?;
            let decoded = engine.metrics.counter("engine.decoded_tokens").get() - before;
            let tps = decoded as f64 / t0.elapsed().as_secs_f64();
            table.row(vec![
                len.to_string(),
                name.to_string(),
                fmt_bytes(bytes),
                format!("{tps:.1}"),
            ]);
            eprintln!("  [{name} @ {len}] done");
        }
    }
    println!("{}", table.render());
    println!(
        "paper shape: ours ~5x smaller than full, throughput above full;\n\
         KIVI matches memory but decode lags (decompress-then-compute)"
    );
    Ok(())
}
