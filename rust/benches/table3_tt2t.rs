//! **Table 3**: Time-To-2nd-Token (prefill + first decode step) across
//! prompt lengths and methods {Ours, KIVI, FlashAttention2(full)}.
//!
//! Paper lengths are 8K–64K on GPUs; this testbed scales to the AOT
//! prefill buckets {256, 1024, 4096}. The paper's claims re-checked:
//! (i) ours ≈ full + small % (compression amortizes into prefill);
//! (ii) the compressed cache admits longer contexts at fixed memory
//! (shown as the cache-bytes column — the OOM column of the paper).

mod common;

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;
use std::time::{Duration, Instant};

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::substrate::benchkit::{fmt_bytes, fmt_duration, Table};
use selfindex_kv::workloads::corpus::{context_with_facts, KvFact};
use selfindex_kv::substrate::rng::Rng;

const LENGTHS: &[usize] = &[256, 1024, 4096];
const METHODS: &[(&str, MethodKind)] = &[
    ("Ours", MethodKind::SelfIndex),
    ("KIVI", MethodKind::Kivi),
    ("Flash Attention2", MethodKind::Full),
];

fn tt2t(engine: &mut Engine, prompt: Vec<u8>) -> anyhow::Result<Duration> {
    let t0 = Instant::now();
    engine.submit(prompt, 2)?; // prefill token + 1 decode step
    engine.run_to_completion()?;
    Ok(t0.elapsed())
}

fn main() -> anyhow::Result<()> {
    if !common::artifacts_available() {
        println!("(artifacts missing — run `make artifacts`)");
        return Ok(());
    }
    let fast = common::fast_mode();
    let lengths: &[usize] = if fast { &LENGTHS[..2] } else { LENGTHS };
    let iters = if fast { 1 } else { 3 };

    println!("== Table 3: TT2T (prefill + 1 decode) ==\n");
    let mut table = Table::new(&[
        "Prompt Length",
        "Ours",
        "KIVI",
        "Flash Attention2",
        "Ours cache",
        "KIVI cache",
        "Full cache",
    ]);
    let mut engines: Vec<Engine> = METHODS
        .iter()
        .map(|&(_, kind)| {
            Engine::new(
                Path::new(&common::artifact_dir()),
                EngineConfig { max_batch: 1, max_new_tokens: 2, ..Default::default() },
                kind,
            )
        })
        .collect::<Result<_, _>>()?;

    for &len in lengths {
        let mut r = Rng::new(len as u64);
        let fact = KvFact::random(&mut r);
        let mut times = vec![];
        let mut caches = vec![];
        for engine in engines.iter_mut() {
            let mut best = Duration::MAX;
            let mut cache_bytes = 0;
            for _ in 0..iters {
                let prompt = {
                    let mut p =
                        context_with_facts(&mut r, len - 8, &[fact.clone()], &[0.4]);
                    p.extend_from_slice(&fact.query());
                    p
                };
                // capture cache footprint right after prefill: run one step
                let t0 = Instant::now();
                engine.submit(prompt, 2)?;
                while engine.running() == 0 && !engine.idle() {
                    engine.step()?; // the prefill step
                }
                cache_bytes = engine.cache_bytes();
                engine.run_to_completion()?;
                best = best.min(t0.elapsed());
            }
            times.push(best);
            caches.push(cache_bytes);
        }
        table.row(vec![
            format!("{len}"),
            fmt_duration(times[0]),
            fmt_duration(times[1]),
            fmt_duration(times[2]),
            fmt_bytes(caches[0]),
            fmt_bytes(caches[1]),
            fmt_bytes(caches[2]),
        ]);
        eprintln!("  [len {len}] done");
    }
    println!("{}", table.render());
    println!("paper shape: ours within ~5% of full TT2T; compressed cache ~4-5x smaller\n\
              (paper's OOM rows correspond to the full/KIVI cache columns growing fastest)");
    let _ = tt2t; // kept for API symmetry in docs
    Ok(())
}
