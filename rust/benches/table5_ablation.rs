//! **Table 5**: component ablations — "w/o sign in quant", "sign-only
//! retrieval", "w/o sink tokens" — plus the §Overhead memory audit.
//!
//! Protocol mirrors the paper: identical states, one config knob flipped
//! per row. Columns: retrieval recall@96, attention output cosine vs full
//! attention, and task accuracy on the engine when artifacts exist
//! (needle subset standing in for MF-en/HPQA/GovRpt/RB-P; pass
//! --no-engine or unset artifacts to skip).

mod common;

use selfindex_kv::baselines::{AttentionMethod, FullCache, SelfIndexing};
use selfindex_kv::eval::{cosine, mean, recall_at_k};
use selfindex_kv::kvcache::layout::RecordLayout;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::{fmt_bytes, Table};

fn fidelity(cfg: &SelfIndexConfig, trials: u64, tokens: usize) -> (f64, f64) {
    let (dim, budget) = (64, 96);
    let mut recalls = vec![];
    let mut cosines = vec![];
    for seed in 0..trials {
        let (keys, vals, query) = common::clustered_state(300 + seed, tokens, dim);
        let mut ours = SelfIndexing::new(dim, cfg.clone());
        // observation window aligned with the query (sink selection signal)
        let qw: Vec<f32> = (0..8).flat_map(|_| query.clone()).collect();
        ours.prefill(&keys, &vals, &qw, 1);
        let mut full = FullCache::new(dim);
        full.prefill(&keys, &vals, &[], 1);

        let approx = ours.retrieval_scores(&query).unwrap();
        let mu = ours.cache().mu().to_vec();
        let centered: Vec<f32> = keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let mut exact = Vec::new();
        selfindex_kv::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);
        recalls.push(recall_at_k(&approx, &exact, budget));

        let mut a = vec![0.0; dim];
        let mut b = vec![0.0; dim];
        ours.attend(&query, budget, &mut a);
        full.attend(&query, usize::MAX, &mut b);
        cosines.push(cosine(&a, &b));
    }
    (mean(&recalls), mean(&cosines))
}

fn main() {
    let tokens = if common::fast_mode() { 1024 } else { 2048 };
    let trials = if common::fast_mode() { 3 } else { 8 };

    println!("== Table 5: ablation study ({trials} heads × {tokens} tokens) ==\n");

    let base = SelfIndexConfig::default();
    let mut variants: Vec<(&str, SelfIndexConfig)> = vec![("Ours", base.clone())];
    let mut v = base.clone();
    v.sign_plane_quant = false;
    variants.push(("w/o sign in quant", v));
    let mut v = base.clone();
    v.magnitude_centroids = false;
    variants.push(("sign-only retrieval", v));
    let mut v = base.clone();
    v.use_sinks = false;
    variants.push(("w/o sink tokens", v));

    let mut table = Table::new(&["Setting", "recall@96", "output cosine"]);
    for (name, cfg) in &variants {
        let (rec, cos) = fidelity(cfg, trials, tokens);
        table.row(vec![name.to_string(), format!("{rec:.3}"), format!("{cos:.4}")]);
    }
    println!("{}", table.render());
    println!("paper shape: w/o-sign and w/o-sink degrade sharply (reproduced).\n\
              sign-only retrieval's gap needs real-LLM key statistics where\n\
              orthant magnitudes differ systematically — on synthetic states\n\
              the magnitude centroids add little (noted in EXPERIMENTS.md).\n");

    // ---- §Overhead memory audit (exact bit accounting) ----
    println!("== memory audit (paper §Overhead Analysis) ==\n");
    let mut mt = Table::new(&["head_dim", "bits/token", "fp16 bits", "savings", "ratio"]);
    for hd in [64usize, 128] {
        let l = RecordLayout::new(hd, &base);
        let full = RecordLayout::baseline_bytes_per_token(16, hd);
        mt.row(vec![
            hd.to_string(),
            (l.bytes_per_token() * 8).to_string(),
            (full * 8).to_string(),
            format!("{:.1}%", 100.0 * l.savings_vs_fp16()),
            format!("{:.2}x", full as f64 / l.bytes_per_token() as f64),
        ]);
    }
    println!("{}", mt.render());
    println!("paper: 896 bits/token @ head_dim 128 -> 78% savings, ~4.6x");

    // ---- measured footprint sanity ----
    let (keys, vals, _) = common::clustered_state(1, tokens, 64);
    let mut ours = SelfIndexing::new(64, base);
    ours.prefill(&keys, &vals, &[], 1);
    let mut full = FullCache::new(64);
    full.prefill(&keys, &vals, &[], 1);
    println!(
        "\nmeasured @ {tokens} tokens: ours {} vs full-f32 {} ({:.2}x)",
        fmt_bytes(ours.memory_bytes()),
        fmt_bytes(full.memory_bytes()),
        full.memory_bytes() as f64 / ours.memory_bytes() as f64
    );
}
