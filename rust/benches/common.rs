//! Shared helpers for the table/figure bench binaries.
#![allow(dead_code)]

use selfindex_kv::substrate::error as anyhow;
use selfindex_kv::substrate::rng::Rng;

/// Synthetic transformer-like key/value state: clustered directions with
/// per-channel offsets (what entropy-aware normalization targets), plus a
/// query aligned with cluster 0.
pub fn clustered_state(
    seed: u64,
    tokens: usize,
    dim: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    let n_dir = 10;
    // mild per-channel scale spread (trained-LLM-like anisotropy) — this
    // is what makes magnitude-bearing centroids beat sign-only ones
    let scales: Vec<f32> = (0..dim).map(|_| (0.4 * r.normal_f32()).exp()).collect();
    let dirs: Vec<Vec<f32>> = (0..n_dir)
        .map(|_| {
            let v: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter().map(|x| 5.0 * x / n).collect()
        })
        .collect();
    let offset: Vec<f32> = (0..dim).map(|_| 0.8 * r.normal_f32()).collect();
    let mut keys = Vec::with_capacity(tokens * dim);
    for _ in 0..tokens {
        let c = r.below(n_dir as u64) as usize;
        for j in 0..dim {
            keys.push(
                scales[j] * (dirs[c][j] + 0.4 * r.normal_f32()) + offset[j],
            );
        }
    }
    let vals: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
    let query: Vec<f32> = (0..dim)
        .map(|j| scales[j] * (dirs[0][j] + 0.2 * r.normal_f32()))
        .collect();
    (keys, vals, query)
}

/// `SIKV_BENCH_FAST=1` shrinks workloads for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("SIKV_BENCH_FAST").is_ok()
}

/// Artifact dir (engine-based benches); honors SIKV_ARTIFACTS.
pub fn artifact_dir() -> String {
    std::env::var("SIKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

pub fn artifacts_available() -> bool {
    std::path::Path::new(&artifact_dir()).join("manifest.json").exists()
}

use std::collections::BTreeMap;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::workloads::EvalItem;

/// Run eval items through a fresh engine with `method`; returns per-task
/// mean scores. One request at a time (accuracy protocol, like the
/// paper's single-sequence evaluation).
pub fn run_eval(
    method: MethodKind,
    items: &[EvalItem],
    mut cfg: EngineConfig,
) -> anyhow::Result<BTreeMap<&'static str, f64>> {
    cfg.max_batch = 1;
    let mut engine = Engine::new(
        std::path::Path::new(&artifact_dir()),
        cfg,
        method,
    )?;
    let mut sums: BTreeMap<&'static str, (f64, usize)> = BTreeMap::new();
    for item in items {
        let new_tokens = item.expected.len().clamp(1, 8);
        engine.submit(item.prompt.clone(), new_tokens)?;
        let results = engine.run_to_completion()?;
        let score = item.score(&results[0].generated);
        let e = sums.entry(item.task).or_insert((0.0, 0));
        e.0 += score;
        e.1 += 1;
    }
    Ok(sums
        .into_iter()
        .map(|(k, (s, n))| (k, s / n as f64))
        .collect())
}

/// Fidelity protocol shared by table1/table2: identical synthetic states,
/// per-method (recall@budget, output cosine vs full attention).
pub fn run_fidelity(
    make: &dyn Fn() -> Box<dyn selfindex_kv::baselines::AttentionMethod>,
    trials: u64,
    tokens: usize,
    budget: usize,
) -> (f64, f64) {
    use selfindex_kv::baselines::{AttentionMethod, FullCache};
    use selfindex_kv::eval::{cosine, mean, recall_at_k};
    let dim = 64;
    let mut recalls = vec![];
    let mut cosines = vec![];
    for seed in 0..trials {
        let (keys, vals, query) = clustered_state(900 + seed, tokens, dim);
        let mut m = make();
        let qw: Vec<f32> = (0..8).flat_map(|_| query.clone()).collect();
        m.prefill(&keys, &vals, &qw, 1);
        let mut full = FullCache::new(dim);
        full.prefill(&keys, &vals, &[], 1);
        let mut a = vec![0.0; dim];
        let mut b = vec![0.0; dim];
        m.attend(&query, budget, &mut a);
        full.attend(&query, usize::MAX, &mut b);
        cosines.push(cosine(&a, &b));
        if let Some(approx) = m.retrieval_scores(&query) {
            let mu: Vec<f32> = (0..dim)
                .map(|j| keys.iter().skip(j).step_by(dim).sum::<f32>() / tokens as f32)
                .collect();
            let centered: Vec<f32> = keys
                .iter()
                .enumerate()
                .map(|(i, &v)| v - mu[i % dim])
                .collect();
            let mut exact = Vec::new();
            selfindex_kv::selfindex::score::exact_scores(
                &query, &centered, dim, &mut exact,
            );
            recalls.push(recall_at_k(&approx, &exact, budget));
        }
    }
    (
        if recalls.is_empty() { f64::NAN } else { mean(&recalls) },
        mean(&cosines),
    )
}
