//! Needle-in-a-haystack across methods (the RULER mechanism, standalone):
//! plant facts in long synthetic contexts, serve the retrieval query
//! through the engine under each attention method, and report accuracy +
//! decode latency side by side.
//!
//! Requires artifacts. Run:
//!   cargo run --release --example needle_retrieval -- [context_bytes]

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::substrate::benchkit::{fmt_duration, Table};
use selfindex_kv::workloads::ruler::{self, RulerConfig};

const METHODS: &[(&str, MethodKind)] = &[
    ("full", MethodKind::Full),
    ("snapkv", MethodKind::SnapKv),
    ("quest", MethodKind::Quest),
    ("doublesparse", MethodKind::DoubleSparse),
    ("ours", MethodKind::SelfIndex),
];

fn main() -> anyhow::Result<()> {
    let ctx: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024);
    let artifacts = std::env::var("SIKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let items = ruler::generate(&RulerConfig { context: ctx, items: 3, seed: 11 });
    let needles: Vec<_> = items
        .iter()
        .filter(|i| i.task.starts_with("NS") || i.task.starts_with("NM"))
        .collect();
    println!("{} needle items at context {ctx}B\n", needles.len());

    let mut table = Table::new(&["method", "accuracy", "mean decode step"]);
    for &(name, kind) in METHODS {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 1;
        cfg.max_new_tokens = 5;
        let mut engine = Engine::new(Path::new(&artifacts), cfg, kind)?;
        let mut acc = 0.0;
        for item in &needles {
            engine.submit(item.prompt.clone(), item.expected.len().min(5))?;
            let results = engine.run_to_completion()?;
            acc += item.score(&results[0].generated);
        }
        let step = engine.metrics.histogram("engine.decode_step_latency");
        table.row(vec![
            name.to_string(),
            format!("{:.3}", acc / needles.len() as f64),
            fmt_duration(step.mean()),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
