//! Ablation explorer: sweep the paper's design knobs (sign plane in
//! quantization, magnitude centroids vs sign-only retrieval, sink tokens,
//! quantization bits) over retrieval fidelity + attention quality on
//! synthetic transformer-like states. Pure native — no artifacts needed.
//!
//! Run: `cargo run --release --example ablation_explorer`

use selfindex_kv::baselines::{AttentionMethod, FullCache, SelfIndexing};
use selfindex_kv::eval::{cosine, mean, recall_at_k};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::Table;
use selfindex_kv::substrate::rng::Rng;

fn clustered_state(seed: u64, tokens: usize, dim: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    let n_dir = 10;
    let dirs: Vec<Vec<f32>> = (0..n_dir)
        .map(|_| {
            let v: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter().map(|x| 5.0 * x / n).collect()
        })
        .collect();
    let offset: Vec<f32> = (0..dim).map(|_| 0.8 * r.normal_f32()).collect();
    let mut keys = Vec::with_capacity(tokens * dim);
    for _ in 0..tokens {
        let c = r.below(n_dir as u64) as usize;
        for j in 0..dim {
            keys.push(dirs[c][j] + offset[j] + 0.4 * r.normal_f32());
        }
    }
    let vals: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
    let query: Vec<f32> = (0..dim).map(|j| dirs[0][j] + 0.2 * r.normal_f32()).collect();
    (keys, vals, query)
}

fn evaluate(cfg: &SelfIndexConfig, trials: u64) -> (f64, f64) {
    let (dim, tokens, budget) = (64, 2048, 96);
    let mut recalls = vec![];
    let mut cosines = vec![];
    for seed in 0..trials {
        let (keys, vals, query) = clustered_state(100 + seed, tokens, dim);
        let mut ours = SelfIndexing::new(dim, cfg.clone());
        ours.prefill(&keys, &vals, &[], 1);
        let mut full = FullCache::new(dim);
        full.prefill(&keys, &vals, &[], 1);

        let approx = ours.retrieval_scores(&query).unwrap();
        let mu = ours.cache().mu().to_vec();
        let centered: Vec<f32> = keys
            .iter()
            .enumerate()
            .map(|(i, &v)| v - mu[i % dim])
            .collect();
        let mut exact = Vec::new();
        selfindex_kv::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);
        recalls.push(recall_at_k(&approx, &exact, budget));

        let mut a = vec![0.0; dim];
        let mut b = vec![0.0; dim];
        ours.attend(&query, budget, &mut a);
        full.attend(&query, usize::MAX, &mut b);
        cosines.push(cosine(&a, &b));
    }
    (mean(&recalls), mean(&cosines))
}

fn main() {
    let trials = 5;
    let base = SelfIndexConfig::default();

    let mut variants: Vec<(String, SelfIndexConfig)> = vec![
        ("ours (paper defaults)".into(), base.clone()),
    ];
    let mut v = base.clone();
    v.sign_plane_quant = false;
    variants.push(("w/o sign in quant".into(), v));
    let mut v = base.clone();
    v.magnitude_centroids = false;
    variants.push(("sign-only retrieval".into(), v));
    let mut v = base.clone();
    v.use_sinks = false;
    variants.push(("w/o sink tokens".into(), v));
    for bits in [4u32, 8] {
        let mut v = base.clone();
        v.quant_bits = bits;
        variants.push((format!("{bits}-bit payloads"), v));
    }

    let mut table = Table::new(&["setting", "recall@96", "output cosine"]);
    for (name, cfg) in &variants {
        let (rec, cos) = evaluate(cfg, trials);
        table.row(vec![name.clone(), format!("{rec:.3}"), format!("{cos:.4}")]);
    }
    println!("ablation over {trials} synthetic heads (2048 tokens, dim 64):\n");
    println!("{}", table.render());
    println!("(compare with paper Table 5: every removed component costs fidelity)");
}
