//! Quickstart: the Self-Indexing KVCache algorithm in 60 seconds.
//!
//! No artifacts needed — this tours the core library on synthetic keys:
//! normalize → sign-VQ encode (codes = index AND sign plane) → one-pass
//! codebook → LUT-GEMV retrieval → top-k → fused sparse attention, then
//! prints the memory accounting next to a full-precision cache.
//!
//! Run: `cargo run --release --example quickstart`

use selfindex_kv::baselines::{AttentionMethod, FullCache, SelfIndexing};
use selfindex_kv::eval::{cosine, recall_at_k};
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::fmt_bytes;
use selfindex_kv::substrate::rng::Rng;

fn main() {
    let (tokens, dim) = (4096usize, 64usize);
    let budget = (tokens as f64 * 0.075) as usize; // the paper's 7.5% sparsity
    println!("== Self-Indexing KVCache quickstart ==");
    println!("context {tokens} tokens × head_dim {dim}, dynamic budget {budget}\n");

    // --- synthetic transformer-like keys: clustered directions + offsets
    let mut r = Rng::new(7);
    let n_dir = 12;
    let dirs: Vec<Vec<f32>> = (0..n_dir)
        .map(|_| {
            let v: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter().map(|x| 5.0 * x / n).collect()
        })
        .collect();
    let offset: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
    let mut keys = Vec::with_capacity(tokens * dim);
    for _ in 0..tokens {
        let c = r.below(n_dir as u64) as usize;
        for j in 0..dim {
            keys.push(dirs[c][j] + offset[j] + 0.4 * r.normal_f32());
        }
    }
    let vals: Vec<f32> = (0..tokens * dim).map(|_| r.normal_f32()).collect();
    let query: Vec<f32> = (0..dim).map(|j| dirs[0][j] + 0.2 * r.normal_f32()).collect();
    // plant a few "needle" tokens strongly aligned with the query — the
    // peaked-attention regime long-context retrieval cares about
    let needles = [512usize, 1700, 2900, 3800];
    for &t in &needles {
        for j in 0..dim {
            keys[t * dim + j] = 2.0 * query[j] + offset[j];
        }
    }

    // --- ours vs the full-precision cache
    let mut ours = SelfIndexing::new(dim, SelfIndexConfig::default());
    ours.prefill(&keys, &vals, &[], 1);
    let mut full = FullCache::new(dim);
    full.prefill(&keys, &vals, &[], 1);

    let mut out_ours = vec![0.0; dim];
    let mut out_full = vec![0.0; dim];
    let t0 = std::time::Instant::now();
    ours.attend(&query, budget, &mut out_ours);
    let t_ours = t0.elapsed();
    let t0 = std::time::Instant::now();
    full.attend(&query, usize::MAX, &mut out_full);
    let t_full = t0.elapsed();

    // --- retrieval fidelity: compressed-domain top-k vs exact scores
    let approx = ours.retrieval_scores(&query).unwrap();
    let mut exact = Vec::new();
    // exact scores against the same centered keys the cache stores
    let mu = ours.cache().mu().to_vec();
    let centered: Vec<f32> = keys
        .iter()
        .enumerate()
        .map(|(i, &v)| v - mu[i % dim])
        .collect();
    selfindex_kv::selfindex::score::exact_scores(&query, &centered, dim, &mut exact);

    println!(
        "retrieval recall@{budget} vs exact scores : {:.3}",
        recall_at_k(&approx, &exact, budget)
    );
    let topk = selfindex_kv::selfindex::topk::top_k_indices(&approx, budget);
    let found = needles.iter().filter(|&&n| topk.contains(&(n as u32))).count();
    println!("needles found in top-{budget}              : {found}/{}", needles.len());
    println!("attention output cosine vs full cache   : {:.4}",
             cosine(&out_ours, &out_full));
    println!("attend latency   ours {:?}  vs full {:?}  ({:.1}x)\n",
             t_ours, t_full, t_full.as_secs_f64() / t_ours.as_secs_f64());

    println!("memory: ours {} vs full {} ({:.2}x smaller)",
             fmt_bytes(ours.memory_bytes()),
             fmt_bytes(full.memory_bytes()),
             full.memory_bytes() as f64 / ours.memory_bytes() as f64);
    println!("\n(The same method runs inside the serving engine — see");
    println!(" examples/serve_longcontext.rs for the end-to-end driver.)");
}
