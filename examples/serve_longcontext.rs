//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! load the build-time-trained tiny model via PJRT, serve a batched
//! open-loop trace of long-context requests through the full stack
//! (router → scheduler → PJRT prefill → compressed cache → LUT-GEMV
//! retrieval → fused sparse attention → PJRT decode), and report
//! latency/throughput plus needle-recall accuracy of the generations.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example serve_longcontext -- [method]`

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{Engine, MethodKind};
use selfindex_kv::substrate::benchkit::{fmt_bytes, fmt_duration, Table};
use selfindex_kv::workloads::trace::{self, TraceConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let method = MethodKind::parse(args.first().map(|s| s.as_str()).unwrap_or("selfindex"))
        .expect("method: selfindex|full|kivi|snapkv|quest|doublesparse");
    let artifacts = std::env::var("SIKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let mut cfg = EngineConfig::default();
    cfg.max_batch = 4;
    cfg.max_new_tokens = 8;
    println!("loading engine (artifacts={artifacts}, method={method:?}) ...");
    let mut engine = Engine::new(Path::new(&artifacts), cfg, method)?;

    let tcfg = TraceConfig {
        requests: 12,
        mean_gap_ms: 0.0, // closed burst: stress continuous batching
        prompt_lens: &[256, 512, 1024],
        decode_tokens: 8,
        seed: 2024,
    };
    let reqs = trace::generate(&tcfg);
    // expected values: each trace prompt ends with "?key:" whose
    // continuation should be the planted value
    let expectations: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| {
            // recover the planted fact from the prompt: find last "?k:"
            let p = &r.prompt;
            let qpos = p.iter().rposition(|&b| b == b'?').unwrap();
            let key = &p[qpos + 1..p.len() - 1];
            // find "@key=" earlier
            let pat: Vec<u8> = [b"@".as_ref(), key, b"=".as_ref()].concat();
            let at = p
                .windows(pat.len())
                .position(|w| w == pat.as_slice())
                .expect("fact planted");
            let vstart = at + pat.len();
            let vend = p[vstart..].iter().position(|&b| b == b';').unwrap() + vstart;
            p[vstart..vend].to_vec()
        })
        .collect();

    let t0 = std::time::Instant::now();
    for r in &reqs {
        engine.submit(r.prompt.clone(), r.max_new_tokens)?;
    }
    let mut results = engine.run_to_completion()?;
    let wall = t0.elapsed();
    results.sort_by_key(|r| r.id);

    let mut table = Table::new(&["req", "prompt", "ttft", "latency", "tok/s", "needle", "output"]);
    let mut hits = 0.0;
    for (r, exp) in results.iter().zip(&expectations) {
        let got = &r.generated[..exp.len().min(r.generated.len())];
        let score = selfindex_kv::eval::prefix_accuracy(got, exp);
        hits += score;
        table.row(vec![
            r.id.to_string(),
            format!("{}B", r.prompt_len),
            fmt_duration(r.ttft),
            fmt_duration(r.latency),
            format!("{:.1}", r.decode_tps()),
            format!("{score:.2}"),
            String::from_utf8_lossy(&r.generated).into_owned(),
        ]);
    }
    println!("{}", table.render());
    let total_tokens: usize = results.iter().map(|r| r.generated.len()).sum();
    println!(
        "== {} requests | {} tokens | wall {} | {:.1} tok/s | needle acc {:.2} | kv cache {} ==",
        results.len(),
        total_tokens,
        fmt_duration(wall),
        total_tokens as f64 / wall.as_secs_f64(),
        hits / results.len() as f64,
        fmt_bytes(engine.cache_bytes()),
    );
    println!("\nengine metrics:\n{}", engine.metrics.snapshot());
    Ok(())
}
