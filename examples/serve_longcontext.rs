//! Long-context serving driver, two phases:
//!
//! 1. **Serving bench** (runs everywhere, including CI): the
//!    continuous-batching front-end (`ServingEngine` over the PJRT-free
//!    `NativeExecutor`) replays an open-loop trace with Poisson
//!    (exponential-gap) arrivals on a **virtual clock** (one engine step
//!    = one millisecond, no wall-clock reads, no sleeps) — chunked
//!    prefill, SLOs as virtual deadlines — and emits
//!    `BENCH_serving.json` with TTFT p50/p99, TPOT, tokens/s,
//!    preemption and deadline-miss rates. Every number is a pure
//!    function of the step schedule, so `scripts/bench_check.py` can
//!    gate TTFT and throughput tightly without machine-speed slack.
//!    `SIKV_BENCH_FAST=1` shrinks the trace for smoke runs.
//! 2. **End-to-end validation** (needs artifacts — `make artifacts`):
//!    load the build-time-trained tiny model via PJRT, serve the trace
//!    through the full stack (router → scheduler → PJRT prefill →
//!    compressed cache → LUT-GEMV retrieval → fused sparse attention →
//!    PJRT decode), and report latency/throughput plus needle-recall
//!    accuracy of the generations.
//!
//! Run: `cargo run --release --example serve_longcontext -- [method]`

use selfindex_kv::substrate::error as anyhow;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use selfindex_kv::config::EngineConfig;
use selfindex_kv::coordinator::{
    Engine, MethodKind, NativeExecutor, Outcome, ServingEngine,
};
use selfindex_kv::kvcache::manager::KvManager;
use selfindex_kv::selfindex::SelfIndexConfig;
use selfindex_kv::substrate::benchkit::{fmt_bytes, fmt_duration, write_bench_json, Table};
use selfindex_kv::substrate::json::{num, obj, s};
use selfindex_kv::workloads::trace::{self, TraceConfig};

fn fast_mode() -> bool {
    std::env::var("SIKV_BENCH_FAST").is_ok()
}

/// Exact quantile by nearest-rank over a sorted sample.
fn quantile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

fn serving_bench(fast: bool) -> anyhow::Result<()> {
    const DIM: usize = 64;
    const BT: usize = 64;
    const CHUNK: usize = 256;
    let si = SelfIndexConfig::default();
    // 512 blocks = 32K cache tokens: comfortably holds the running set,
    // so the reported preemption rate reflects policy, not starvation
    let mgr = Arc::new(KvManager::for_head(DIM, &si, BT, 512));
    let exec = NativeExecutor::new(DIM, 1, 1, 1, 48, si, Arc::clone(&mgr));
    let cfg = EngineConfig {
        block_tokens: BT,
        prefill_chunk_tokens: CHUNK,
        max_batch: 8,
        ..EngineConfig::default()
    };
    // one engine step = 1 ms of virtual time: arrivals, deadlines, TTFT
    // and latency all live on the step clock, making the replay (and the
    // gated metrics) bit-deterministic across machines
    let tick = Duration::from_millis(1);
    let mut eng = ServingEngine::new(cfg, exec)?.with_virtual_clock(tick);

    let tcfg = TraceConfig {
        requests: if fast { 16 } else { 48 },
        mean_gap_ms: if fast { 2.0 } else { 5.0 },
        prompt_lens: &[256, 512, 1024],
        decode_tokens: 16,
        seed: 2024,
        slo_ms: Some(2_000.0),
    };
    let reqs = trace::generate(&tcfg);
    let n = reqs.len();
    println!(
        "== serving bench: {n} requests, Poisson arrivals (mean gap {:.1} ms), \
         chunked prefill ({CHUNK} tokens), SLO {} ms ==\n",
        tcfg.mean_gap_ms,
        tcfg.slo_ms.unwrap_or(0.0)
    );

    // open-loop replay on the virtual clock: submit each request the
    // first step whose virtual "now" reaches its Poisson arrival offset,
    // then step unconditionally (an idle step still advances the clock
    // toward the next arrival — no sleeps, no wall-clock reads)
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut steps = 0u64;
    while next < n || !eng.is_drained() {
        let vnow = tick * steps as u32;
        while next < n && reqs[next].at <= vnow {
            let r = &reqs[next];
            match r.slo {
                Some(slo) => eng.submit_with_deadline(r.prompt.clone(), r.max_new_tokens, slo),
                None => eng.submit(r.prompt.clone(), r.max_new_tokens),
            }
            .expect("trace fits the admission queue");
            next += 1;
        }
        eng.step()?;
        steps += 1;
    }
    let wall = t0.elapsed();
    let vwall = tick * steps as u32;

    let mut results = eng.take_results();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), n, "every submitted request reaches a result");
    let mut ttft_ms: Vec<f64> = results
        .iter()
        .filter(|r| r.decode_steps > 0)
        .map(|r| r.ttft.as_secs_f64() * 1e3)
        .collect();
    ttft_ms.sort_by(f64::total_cmp);
    let tpots: Vec<f64> = results
        .iter()
        .filter(|r| r.decode_steps > 1)
        .map(|r| {
            r.latency.saturating_sub(r.ttft).as_secs_f64() * 1e3 / (r.decode_steps - 1) as f64
        })
        .collect();
    let tpot_ms = tpots.iter().sum::<f64>() / tpots.len().max(1) as f64;
    let total_tokens: usize = results.iter().map(|r| r.generated.len()).sum();
    // throughput on the virtual clock — deterministic, so it gates tight
    let tokens_per_sec = total_tokens as f64 / vwall.as_secs_f64();
    let completed = results.iter().filter(|r| r.outcome == Outcome::Completed).count();
    let misses = results
        .iter()
        .filter(|r| r.outcome == Outcome::DeadlineExceeded)
        .count();
    let preemptions = eng.metrics.counter("engine.preemptions").get();
    let p50 = quantile_ms(&ttft_ms, 0.50);
    let p99 = quantile_ms(&ttft_ms, 0.99);

    let mut tab = Table::new(&["metric", "value"]);
    tab.row(vec!["completed".into(), format!("{completed}/{n}")]);
    tab.row(vec!["ttft p50".into(), format!("{p50:.2} ms")]);
    tab.row(vec!["ttft p99".into(), format!("{p99:.2} ms")]);
    tab.row(vec!["tpot (mean)".into(), format!("{tpot_ms:.3} ms")]);
    tab.row(vec!["throughput".into(), format!("{tokens_per_sec:.0} tok/s")]);
    tab.row(vec!["preemptions".into(), preemptions.to_string()]);
    tab.row(vec!["deadline misses".into(), format!("{misses}/{n}")]);
    tab.row(vec!["virtual wall".into(), format!("{} ({steps} steps)", fmt_duration(vwall))]);
    tab.row(vec!["real wall".into(), fmt_duration(wall)]);
    println!("{}", tab.render());

    let payload = obj(vec![
        ("bench", s("serving")),
        ("requests", num(n as f64)),
        ("completed", num(completed as f64)),
        ("ttft_p50_ms", num(p50)),
        ("ttft_p99_ms", num(p99)),
        ("tpot_ms", num(tpot_ms)),
        ("tokens_per_sec", num(tokens_per_sec)),
        ("preemption_rate", num(preemptions as f64 / n as f64)),
        ("deadline_miss_rate", num(misses as f64 / n as f64)),
        ("chunk_tokens", num(CHUNK as f64)),
        ("virtual_secs", num(vwall.as_secs_f64())),
        ("steps", num(steps as f64)),
        ("wall_secs", num(wall.as_secs_f64())),
    ]);
    match write_bench_json("serving", payload) {
        Ok(p) => println!("wrote {}\n", p.display()),
        Err(e) => eprintln!("failed to write BENCH_serving.json: {e}\n"),
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    serving_bench(fast_mode())?;

    let args: Vec<String> = std::env::args().skip(1).collect();
    let method = MethodKind::parse(args.first().map(|s| s.as_str()).unwrap_or("selfindex"))
        .expect("method: selfindex|full|kivi|snapkv|quest|doublesparse");
    let artifacts = std::env::var("SIKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !Path::new(&artifacts).join("manifest.json").exists() {
        println!("(artifacts missing — PJRT needle-recall phase skipped; run `make artifacts`)");
        return Ok(());
    }

    let mut cfg = EngineConfig::default();
    cfg.max_batch = 4;
    cfg.max_new_tokens = 8;
    println!("loading engine (artifacts={artifacts}, method={method:?}) ...");
    let mut engine = Engine::new(Path::new(&artifacts), cfg, method)?;

    let tcfg = TraceConfig {
        requests: 12,
        mean_gap_ms: 0.0, // closed burst: stress continuous batching
        prompt_lens: &[256, 512, 1024],
        decode_tokens: 8,
        seed: 2024,
        slo_ms: None,
    };
    let reqs = trace::generate(&tcfg);
    // expected values: each trace prompt ends with "?key:" whose
    // continuation should be the planted value
    let expectations: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| {
            // recover the planted fact from the prompt: find last "?k:"
            let p = &r.prompt;
            let qpos = p.iter().rposition(|&b| b == b'?').unwrap();
            let key = &p[qpos + 1..p.len() - 1];
            // find "@key=" earlier
            let pat: Vec<u8> = [b"@".as_ref(), key, b"=".as_ref()].concat();
            let at = p
                .windows(pat.len())
                .position(|w| w == pat.as_slice())
                .expect("fact planted");
            let vstart = at + pat.len();
            let vend = p[vstart..].iter().position(|&b| b == b';').unwrap() + vstart;
            p[vstart..vend].to_vec()
        })
        .collect();

    let t0 = std::time::Instant::now();
    for r in &reqs {
        engine.submit(r.prompt.clone(), r.max_new_tokens)?;
    }
    let mut results = engine.run_to_completion()?;
    let wall = t0.elapsed();
    results.sort_by_key(|r| r.id);

    let mut table = Table::new(&["req", "prompt", "ttft", "latency", "tok/s", "needle", "output"]);
    let mut hits = 0.0;
    for (r, exp) in results.iter().zip(&expectations) {
        let got = &r.generated[..exp.len().min(r.generated.len())];
        let score = selfindex_kv::eval::prefix_accuracy(got, exp);
        hits += score;
        table.row(vec![
            r.id.to_string(),
            format!("{}B", r.prompt_len),
            fmt_duration(r.ttft),
            fmt_duration(r.latency),
            format!("{:.1}", r.decode_tps()),
            format!("{score:.2}"),
            String::from_utf8_lossy(&r.generated).into_owned(),
        ]);
    }
    println!("{}", table.render());
    let total_tokens: usize = results.iter().map(|r| r.generated.len()).sum();
    println!(
        "== {} requests | {} tokens | wall {} | {:.1} tok/s | needle acc {:.2} | kv cache {} ==",
        results.len(),
        total_tokens,
        fmt_duration(wall),
        total_tokens as f64 / wall.as_secs_f64(),
        hits / results.len() as f64,
        fmt_bytes(engine.cache_bytes()),
    );
    println!("\nengine metrics:\n{}", engine.metrics.snapshot());
    Ok(())
}
